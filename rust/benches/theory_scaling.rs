//! Theory benches: Proposition 1 ((n+d)log² scaling), Corollary 1 (PAC
//! power-law regimes), Theorem 1 (error <= delta, M <= bound).

use bmonn::bench_harness::figures;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    println!("{}", figures::prop1(quick, 42).render());
    println!("{}", figures::cor1(quick, 42).render());
    println!("{}", figures::thm1(quick, 42).render());
}
