//! Fig 5: BMO k-means assignment-step gain over exact Lloyd's.

use bmonn::bench_harness::figures;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    println!("{}", figures::fig5(quick, 42).render());
}
