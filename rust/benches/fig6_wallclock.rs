//! Fig 6: wall-clock comparison — BMO-NN (native and, when artifacts are
//! built, PJRT) vs exact scan vs LSH, varying d. Index-construction time
//! is excluded for all methods (the paper's accounting).

use std::time::Instant;

use bmonn::baselines::exact;
use bmonn::baselines::lsh::{LshIndex, LshParams};
use bmonn::bench_harness::{fmt_f, Report};
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    let (n, k, nq) = if quick { (600, 5, 10) } else { (2000, 5, 30) };
    let dims: &[usize] = if quick { &[256, 1024, 4096] }
                         else { &[256, 1024, 4096, 8192] };
    let mut rep = Report::new(
        "Fig 6: wall-clock per query (index construction excluded)",
        &["d", "algo", "us/query", "speedup vs exact"]);
    for &d in dims {
        let data = synthetic::image_like(n, d, 42);
        let params = BanditParams { k, ..Default::default() };

        // exact scan
        let t0 = Instant::now();
        for q in 0..nq {
            let _ = exact::knn_point(&data, q, k, Metric::L2Sq,
                                     &mut Counter::new());
        }
        let exact_us = t0.elapsed().as_micros() as f64 / nq as f64;

        // BMO native
        let mut engine = NativeEngine::default();
        let mut rng = Rng::new(1);
        let t1 = Instant::now();
        for q in 0..nq {
            let mut qrng = rng.fork(q as u64);
            let _ = knn_point_dense(&data, q, Metric::L2Sq, &params,
                                    &mut engine, &mut qrng,
                                    &mut Counter::new());
        }
        let bmo_us = t1.elapsed().as_micros() as f64 / nq as f64;

        // LSH (prebuilt index, query only)
        let mut rng2 = Rng::new(2);
        let idx = LshIndex::build(&data, Metric::L2Sq,
                                  &LshParams { n_tables: 32, n_hashes: 8,
                                               w: 4.0 },
                                  &mut rng2);
        let t2 = Instant::now();
        for q in 0..nq {
            let _ = idx.knn_query(data.row(q), Some(q), k,
                                  &mut Counter::new());
        }
        let lsh_us = t2.elapsed().as_micros() as f64 / nq as f64;

        for (name, us) in [("exact", exact_us), ("BMO-NN", bmo_us),
                           ("LSH", lsh_us)] {
            rep.row(vec![d.to_string(), name.into(), fmt_f(us, 0),
                         format!("{:.2}x", exact_us / us)]);
        }
    }
    rep.note("paper: BMO-NN ~1.5x faster than optimized exact and ~5x \
              faster than LSH at d=12288; crossover vs exact appears as d \
              grows");
    println!("{}", rep.render());
}
