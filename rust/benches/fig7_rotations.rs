//! Fig 7 (Appendix C-B): random HD rotations flatten coordinate-wise
//! distance tails, shrinking the Hoeffding sub-Gaussian bound (Lemma 3).

use bmonn::bench_harness::figures;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    println!("{}", figures::fig7(quick, 42).render());
}
