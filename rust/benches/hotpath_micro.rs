//! Microbenchmarks of the L3 hot path (docs/ARCHITECTURE.md, "Hot-path
//! kernels and the pull engines"): per-engine pull throughput across the
//! dispatched kernel tiers, bandit-loop overhead per round, and heap op
//! costs. This is the profile driver for the performance pass.

use std::hint::black_box;
use std::time::Instant;

use bmonn::bench_harness::{fmt_f, Report};
use bmonn::coordinator::arms::{ArmSet, DenseArms, PullEngine, ScalarEngine};
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::BanditParams;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn bench<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() {
    let (n, d) = (2048, 1024);
    let data = synthetic::image_like(n, d, 7);
    let query = data.row_vec(0);
    let rows: Vec<u32> = (1..33).collect();
    let mut rng = Rng::new(8);
    let coords: Vec<u32> = (0..256).map(|_| rng.below(d) as u32).collect();
    let mut rep = Report::new(
        "hot-path microbenchmarks",
        &["op", "ns/op", "ns/coordinate", "notes"]);

    // engine partial_sums: 32 arms x 256 coords = 8192 coord ops
    let coord_ops = (rows.len() * coords.len()) as f64;
    let mut scalar = ScalarEngine;
    let (mut s, mut q) = (Vec::new(), Vec::new());
    let ns = bench(200, || {
        scalar.partial_sums(&data, &query, &rows, &coords, Metric::L2Sq,
                            &mut s, &mut q);
        black_box(&s);
    });
    rep.row(vec!["scalar partial_sums 32x256".into(), fmt_f(ns, 0),
                 fmt_f(ns / coord_ops, 2), "reference".into()]);
    let mut native = NativeEngine::default();
    let ns = bench(200, || {
        native.partial_sums(&data, &query, &rows, &coords, Metric::L2Sq,
                            &mut s, &mut q);
        black_box(&s);
    });
    rep.row(vec!["native partial_sums 32x256".into(), fmt_f(ns, 0),
                 fmt_f(ns / coord_ops, 2),
                 format!("hot path [{}]",
                         native.kernel_tier().as_str())]);

    // each kernel tier this host can run, forced explicitly — the
    // scalar row is the dispatch-free anchor the SIMD rows are read
    // against
    for choice in [KernelChoice::Scalar, KernelChoice::Avx2,
                   KernelChoice::Neon] {
        let mut forced = match NativeEngine::with_options(choice, false) {
            Ok(e) => e,
            Err(_) => continue,
        };
        let tier = forced.kernel_tier().as_str();
        let ns = bench(200, || {
            forced.partial_sums(&data, &query, &rows, &coords,
                                Metric::L2Sq, &mut s, &mut q);
            black_box(&s);
        });
        rep.row(vec![format!("forced {tier} partial_sums 32x256"),
                     fmt_f(ns, 0), fmt_f(ns / coord_ops, 2),
                     "kernel tier".into()]);
    }

    // exact distances
    let ns = bench(200, || {
        native.exact_dists(&data, &query, &rows, Metric::L2Sq, &mut s);
        black_box(&s);
    });
    rep.row(vec!["native exact_dists 32 rows".into(), fmt_f(ns, 0),
                 fmt_f(ns / (rows.len() * d) as f64, 2), "".into()]);

    // full arm-set pull_batch (includes coordinate sampling)
    let mut engine = NativeEngine::default();
    let cand = DenseArms::<NativeEngine>::candidates(n, Some(0));
    let mut arms = DenseArms::new(&data, &query, &cand, Metric::L2Sq,
                                  &mut engine);
    let sel: Vec<usize> = (0..32).collect();
    let mut c = Counter::new();
    let mut rng2 = Rng::new(9);
    let ns = bench(200, || {
        arms.pull_batch(&sel, 256, &mut rng2, &mut c, &mut s, &mut q);
        black_box(&s);
    });
    rep.row(vec!["pull_batch 32x256 (incl sampling)".into(), fmt_f(ns, 0),
                 fmt_f(ns / coord_ops, 2), "".into()]);

    // whole-query bandit: end-to-end per-query cost and per-unit overhead
    let params = BanditParams { k: 5, ..Default::default() };
    let mut units_total = 0u64;
    let mut queries = 0u64;
    let mut engine2 = NativeEngine::default();
    let ns = bench(20, || {
        let mut qrng = Rng::new(queries);
        let mut cc = Counter::new();
        let r = knn_point_dense(&data, (queries % 64) as usize,
                                Metric::L2Sq, &params, &mut engine2,
                                &mut qrng, &mut cc);
        black_box(&r);
        units_total += cc.get();
        queries += 1;
    });
    let units_per_query = units_total as f64 / queries as f64;
    rep.row(vec!["full 5-NN query (n=2048 d=1024)".into(), fmt_f(ns, 0),
                 fmt_f(ns / units_per_query, 2),
                 format!("{units_per_query:.0} units/query")]);
    println!("{}", rep.render());
}
