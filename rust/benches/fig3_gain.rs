//! Fig 2 / Fig 3: gain in coordinate-wise distance computations over exact
//! computation, varying n (3a) and d (3b), for BMO-NN vs LSH / kGraph /
//! NGT. Run with `cargo bench --bench fig3_gain` (add BMONN_FULL=1 for the
//! full-size sweep).

use bmonn::bench_harness::figures;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    let shards = std::env::var("BMONN_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let seed = 42;
    println!("{}", figures::fig3a(quick, seed, shards).render());
    println!("{}", figures::fig3b(quick, seed, shards).render());
}
