//! Fig 4(a): adaptivity ablation (uniform sampling at multiples of BMO's
//! budget); Fig 4(b): sparse Monte Carlo box gains on gene-like data;
//! Fig 4(c): coordinate-distance histograms.

use bmonn::bench_harness::figures;

fn main() {
    let quick = std::env::var_os("BMONN_FULL").is_none();
    let shards = std::env::var("BMONN_SHARDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let seed = 42;
    println!("{}", figures::fig4a(quick, seed, shards).render());
    println!("{}", figures::fig4b(quick, seed).render());
    println!("{}", figures::fig4c(quick, seed).render());
}
