//! Elastic shard ring: live resharding under real sockets.
//!
//! The ring must be able to *change shape under traffic*: staging
//! servers come up empty, a coordinator streams each its row range
//! (`TransferBegin`/`TransferRows`/`TransferCommit`), the installed
//! placement is verified fingerprint-by-fingerprint, and clients flip
//! onto it at the next placement epoch — with **zero query errors and
//! bitwise-identical answers on both sides of the flip**. The old
//! placement is never mutated, so any mid-transfer failure leaves it
//! serving untouched.
//!
//! Covered here, end to end:
//! * doubling a 2-shard ring to 4 shards while a query workload keeps
//!   running against the old placement — every answer before, during
//!   and after the transfer stays bitwise-identical to solo
//!   `NativeEngine`;
//! * the coordinator's `reshard` admin op: the placement flips, the
//!   result-cache epoch auto-bumps (an old-epoch cache entry can never
//!   serve a post-flip query), and traffic drains onto the new ring;
//! * a flapping transfer target (seeded `FaultProxy` severs the stream
//!   mid-chunk): the failed transfer surfaces as a clean error and a
//!   retry restarts from scratch — never resuming into a corrupt
//!   buffer;
//! * a commit whose fingerprint disagrees with the received bytes is
//!   refused and discards the staged rows;
//! * epoch hygiene: a client pinned to the wrong placement epoch is
//!   refused at connect, and a serving server refuses `TransferBegin`.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::http::http_request;
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::server::{Server, ServerConfig};
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::fault::{Dir, FaultAction, FaultPlan, FaultProxy,
                            FaultRule};
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::placement::PlacementMap;
use bmonn::runtime::remote::{endpoint_stats, reshard_to,
                             spawn_loopback_ring, transfer_shard,
                             RemoteEngine, RemoteOptions, RingClient,
                             ShardServer};
use bmonn::runtime::wire::{self, Message};
use bmonn::util::json::Json;
use bmonn::util::rng::Rng;

const TIMEOUT: Option<Duration> = Some(Duration::from_secs(5));

fn opts(expect_epoch: Option<u64>) -> RemoteOptions {
    RemoteOptions {
        timeout: TIMEOUT,
        expect_epoch,
        ..RemoteOptions::default()
    }
}

/// Start `n` empty staging servers on loopback ephemeral ports.
fn staging_ring(n: usize) -> (Vec<ShardServer>, Vec<String>) {
    let mut servers = Vec::with_capacity(n);
    let mut eps = Vec::with_capacity(n);
    for _ in 0..n {
        let s = ShardServer::start_staging("127.0.0.1:0",
                                           KernelChoice::Auto, TIMEOUT)
            .expect("staging server");
        eps.push(s.endpoint());
        servers.push(s);
    }
    (servers, eps)
}

/// Reference answer: solo `NativeEngine` under the same seeded rng
/// stream every substrate must reproduce bitwise.
fn solo_answer(ds: &DenseDataset, q: usize, params: &BanditParams,
               seed: u64) -> (Vec<u32>, Vec<f64>) {
    let mut solo = NativeEngine::default();
    let mut rng = Rng::new(seed);
    let mut c = Counter::new();
    let r = knn_point_dense(ds, q, Metric::L2Sq, params, &mut solo,
                            &mut rng, &mut c);
    (r.ids, r.dists)
}

fn remote_answer(ds: &DenseDataset, q: usize, params: &BanditParams,
                 seed: u64, eng: &mut RemoteEngine) -> (Vec<u32>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut c = Counter::new();
    let r = knn_point_dense(ds, q, Metric::L2Sq, params, eng, &mut rng,
                            &mut c);
    (r.ids, r.dists)
}

#[test]
fn ring_doubles_mid_workload_with_zero_errors_and_bitwise_answers() {
    let ds = synthetic::image_like(96, 32, 41);
    let params = BanditParams { k: 3, delta: 0.01, ..Default::default() };
    let queries: Vec<usize> = (0..12).map(|i| (i * 11) % 96).collect();
    let solo: Vec<(Vec<u32>, Vec<f64>)> = queries
        .iter()
        .enumerate()
        .map(|(i, &q)| solo_answer(&ds, q, &params, 1000 + i as u64))
        .collect();
    // old placement: a 2-shard ring at the default placement epoch 0
    let (old_ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let old_map = PlacementMap::parse(&endpoints).unwrap();
    let engine =
        RemoteEngine::connect_opts(&old_map, opts(Some(0))).unwrap();
    // new placement: double the shard count onto empty staging servers
    let (staged, new_eps) = staging_ring(4);
    let new_map = PlacementMap::parse(&new_eps).unwrap();
    // the workload keeps querying the OLD placement while the transfer
    // streams — resharding must cause zero query errors, and every
    // answer must stay bitwise-identical to solo execution
    let done = AtomicBool::new(false);
    let (engine, waves, fps) = std::thread::scope(|sc| {
        let driver = sc.spawn(|| {
            let mut engine = engine;
            let mut waves = 0u64;
            while !done.load(Ordering::Relaxed) || waves == 0 {
                for (i, &q) in queries.iter().enumerate() {
                    let got = remote_answer(&ds, q, &params,
                                            1000 + i as u64, &mut engine);
                    assert_eq!(got, solo[i],
                               "query {q} diverged mid-transfer");
                }
                waves += 1;
            }
            (engine, waves)
        });
        let fps = reshard_to(&ds, &new_map, 1, TIMEOUT)
            .expect("reshard onto staging servers");
        done.store(true, Ordering::Relaxed);
        let (engine, waves) = driver.join().expect("workload driver");
        (engine, waves, fps)
    });
    assert!(waves >= 1, "the workload never ran during the transfer");
    // the transfer verified fingerprints endpoint by endpoint; pin the
    // first shard's against an independent local computation
    assert_eq!(fps.len(), 4);
    let rows = ds.raw()[..24 * ds.d].to_vec();
    let slice0 = DenseDataset::new(24, ds.d, rows);
    assert_eq!(fps[0], wire::dataset_fingerprint(ds.n, 0, &slice0),
               "shard 0 fingerprint must match the source bytes");
    // flip: connect pinned to the new epoch, then drop the old ring
    // entirely — the remaining answers can only come from the new
    // placement, and they must still be bitwise-identical
    let client =
        Arc::new(RingClient::connect_opts(&new_map, opts(Some(1)))
            .expect("connect to the resharded ring"));
    assert_eq!(client.epoch(), 1, "new ring must agree on epoch 1");
    let mut fresh = RemoteEngine::from_client(client);
    drop(engine);
    drop(old_ring);
    for (i, &q) in queries.iter().enumerate() {
        let got =
            remote_answer(&ds, q, &params, 1000 + i as u64, &mut fresh);
        assert_eq!(got, solo[i], "query {q} diverged after the flip");
    }
    // every new endpoint serves its slice at the new epoch
    for (shard, ep) in new_eps.iter().enumerate() {
        let st = endpoint_stats(ep, TIMEOUT).unwrap();
        assert_eq!((st.shard, st.of, st.epoch), (shard, 4, 1));
        assert_eq!(st.data_hash, fps[shard]);
    }
    drop(staged);
}

#[test]
fn coordinator_reshard_flips_placement_and_auto_bumps_the_cache() {
    let ds = synthetic::image_like(80, 32, 53);
    let (old_ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints,
        http_port: Some(0),
        cache_entries: 8,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.expect("http_port: Some(0) must bind");
    let metrics = |label: &str| {
        let (status, _, body) =
            http_request(&http, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200, "{label}: {body}");
        Json::parse(body.trim()).unwrap()
    };
    let counter = |m: &Json, key: &str| {
        m.get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("/metrics lost {key}: {m}"))
            as u64
    };
    let body = Json::obj(vec![
        ("query", Json::f32_array(&ds.row_vec(5))),
        ("k", Json::Num(3.0)),
    ])
    .to_string();
    // fresh compute, then a byte-identical cache hit at cache epoch 0
    let (s1, _, fresh) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s1, 200, "{fresh}");
    let (s2, _, hit) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s2, 200);
    assert_eq!(hit, fresh, "cache hit must replay the stored bytes");
    let m = metrics("pre-reshard");
    assert_eq!(counter(&m, "cache_hits"), 1);
    assert_eq!(counter(&m, "epoch"), 0);
    assert_eq!(counter(&m, "placement_epoch"), 0);
    let ring = m.get("ring").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(ring.len(), 2, "per-endpoint health for both shards");
    for ep in ring {
        assert_eq!(ep.get("ok"), Some(&Json::Bool(true)), "{ep}");
        assert_eq!(ep.get("epoch").and_then(|v| v.as_usize()), Some(0));
    }
    // double the ring through the admin op
    let (staged, new_eps) = staging_ring(4);
    let reshard_body = Json::obj(vec![
        ("to",
         Json::Arr(new_eps.iter()
             .map(|e| Json::Str(e.clone()))
             .collect())),
        ("epoch", Json::Num(2.0)),
    ])
    .to_string();
    let (s3, _, resp) =
        http_request(&http, "POST", "/admin/reshard",
                     Some(&reshard_body))
            .unwrap();
    assert_eq!(s3, 200, "reshard must succeed: {resp}");
    let resp = Json::parse(resp.trim()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(resp.get("placement_epoch").and_then(|v| v.as_usize()),
               Some(2));
    // the flip auto-bumped the result-cache epoch: the pre-flip entry
    // can never serve again — no manual /admin/epoch-bump involved
    let m = metrics("post-reshard");
    assert_eq!(counter(&m, "placement_epoch"), 2);
    assert_eq!(counter(&m, "epoch"), 1,
               "a completed reshard must auto-bump the cache epoch");
    let ring = m.get("ring").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(ring.len(), 4, "health now reports the new placement");
    for ep in ring {
        assert_eq!(ep.get("ok"), Some(&Json::Bool(true)), "{ep}");
        assert_eq!(ep.get("epoch").and_then(|v| v.as_usize()), Some(2));
    }
    // the same query recomputes (a miss under the new epoch) and the
    // seeded serving compute answers the same bytes as before the flip
    let hits_before = counter(&m, "cache_hits");
    let (s4, _, recomputed) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s4, 200, "{recomputed}");
    assert_eq!(recomputed, fresh,
               "the post-flip recompute must answer the same bytes — \
                the dataset did not change, only its placement");
    assert_eq!(counter(&metrics("post-flip repeat"), "cache_hits"),
               hits_before,
               "an old-epoch cache entry served a post-flip query");
    // the old placement is fully drained: with its servers gone,
    // queries keep answering through the new ring
    drop(old_ring);
    let other = Json::obj(vec![
        ("query", Json::f32_array(&ds.row_vec(9))),
        ("k", Json::Num(3.0)),
    ])
    .to_string();
    let (s5, _, post) =
        http_request(&http, "POST", "/knn", Some(&other)).unwrap();
    assert_eq!(s5, 200,
               "query after dropping the old ring must be served by \
                the new placement: {post}");
    let post = Json::parse(post.trim()).unwrap();
    assert_eq!(post.get("ok"), Some(&Json::Bool(true)));
    drop(staged);
    srv.stop();
}

#[test]
fn flapping_transfer_target_fails_cleanly_and_a_retry_installs() {
    let ds = synthetic::gaussian_iid(1200, 16, 77);
    let (staged, eps) = staging_ring(1);
    // sever the stream mid-chunk on the second TransferRows frame: the
    // transfer dies with a clean error, nothing half-installs
    let plan = FaultPlan {
        seed: 9,
        rules: vec![FaultRule {
            dir: Dir::ToServer,
            frame: 2,
            action: FaultAction::DropMidFrame,
        }],
        ..Default::default()
    };
    let mut proxy = FaultProxy::start(&eps[0], plan).unwrap();
    let err = transfer_shard(&proxy.endpoint(), &ds, 0, 1, 3, TIMEOUT)
        .expect_err("a severed stream must fail the transfer");
    assert!(err.contains("transfer"), "unexpected error: {err}");
    let st = endpoint_stats(&eps[0], TIMEOUT)
        .expect_err("a flapped target must still be staging");
    assert!(st.contains("staging"), "unexpected error: {st}");
    // the retry restarts from scratch (a fresh TransferBegin replaces
    // the half-streamed state) and installs the verified dataset
    let fp = transfer_shard(&proxy.endpoint(), &ds, 0, 1, 3, TIMEOUT)
        .expect("retry after the flap");
    let st = endpoint_stats(&eps[0], TIMEOUT).unwrap();
    assert_eq!((st.shard, st.of, st.epoch), (0, 1, 3));
    assert_eq!(st.n_total, 1200);
    assert_eq!(st.data_hash, fp);
    assert_eq!(fp, wire::dataset_fingerprint(ds.n, 0, &ds),
               "installed fingerprint must match the source bytes");
    proxy.stop();
    drop(staged);
}

#[test]
fn commit_with_diverged_fingerprint_is_refused() {
    let ds = synthetic::gaussian_iid(8, 4, 3);
    let (staged, eps) = staging_ring(1);
    let mut stream = TcpStream::connect(&eps[0]).unwrap();
    stream.set_read_timeout(TIMEOUT).unwrap();
    stream.set_write_timeout(TIMEOUT).unwrap();
    let mut buf = Vec::new();
    let step = |stream: &mut TcpStream, buf: &mut Vec<u8>| {
        wire::write_frame(stream, buf).unwrap();
        let mut rep = Vec::new();
        wire::read_frame(stream, &mut rep).unwrap();
        Message::decode(&rep).unwrap()
    };
    wire::encode_transfer_begin(&mut buf, 1, 0, 1, 8, 4, 0, 8, 5);
    assert!(matches!(step(&mut stream, &mut buf),
                     Message::Ack { wave_id: 1 }));
    wire::encode_transfer_rows(&mut buf, 2, 0, ds.raw());
    assert!(matches!(step(&mut stream, &mut buf),
                     Message::Ack { wave_id: 2 }));
    // commit claims a fingerprint the received bytes do not hash to:
    // the target must refuse and discard the staged rows
    let fp = wire::dataset_fingerprint(ds.n, 0, &ds);
    wire::encode_transfer_commit(&mut buf, 3, fp ^ 1);
    match step(&mut stream, &mut buf) {
        Message::Error { msg, .. } => {
            assert!(msg.contains("fingerprint mismatch"),
                    "unexpected refusal: {msg}");
        }
        other => panic!("commit with a bad hash must be refused, got \
                         {other:?}"),
    }
    let st = endpoint_stats(&eps[0], TIMEOUT)
        .expect_err("a refused commit must leave the target staging");
    assert!(st.contains("staging"), "unexpected error: {st}");
    // a correct transfer afterwards installs normally
    let got = transfer_shard(&eps[0], &ds, 0, 1, 5, TIMEOUT).unwrap();
    assert_eq!(got, fp);
    assert_eq!(endpoint_stats(&eps[0], TIMEOUT).unwrap().epoch, 5);
    drop(staged);
}

#[test]
fn epoch_pinned_connect_refuses_the_wrong_placement() {
    let ds = synthetic::gaussian_iid(40, 8, 11);
    let (_ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let map = PlacementMap::parse(&endpoints).unwrap();
    let err = RingClient::connect_opts(&map, opts(Some(3)))
        .expect_err("an epoch-0 ring must refuse an epoch-3 pin");
    assert!(err.contains("placement epoch"), "unexpected error: {err}");
    // unpinned connects adopt whatever single epoch the ring agrees on
    let client = RingClient::connect_opts(&map, opts(None)).unwrap();
    assert_eq!(client.epoch(), 0);
}

#[test]
fn serving_servers_refuse_transfers() {
    let ds = synthetic::gaussian_iid(40, 8, 13);
    let (_ring, endpoints) = spawn_loopback_ring(&ds, 1).unwrap();
    let err = transfer_shard(&endpoints[0], &ds, 0, 1, 1, TIMEOUT)
        .expect_err("a serving server must refuse TransferBegin");
    assert!(err.contains("staging server"), "unexpected error: {err}");
}
