//! Speculative cross-round pipelining parity matrix: `--speculate`
//! must be **bitwise-invisible** on every engine substrate. The same
//! batched k-NN workload runs solo (single-threaded `NativeEngine`),
//! locally sharded (`build_host_engine`), over remote loopback rings
//! (`RemoteEngine`, the pipelined substrate where speculation actually
//! engages), and multiplexed (two engines sharing one `RingClient`,
//! including concurrently on separate threads) — each with speculation
//! off and on — and every run's ids, distances and caller-visible
//! `Counter` charge must equal the solo speculation-off reference
//! exactly. Blocking substrates must additionally report all-zero
//! speculation counters even when asked to speculate, and pipelined
//! runs must uphold `speculated == confirmed + discarded` while
//! actually confirming waves (the overlap is real, not vacuous).

use std::sync::Arc;

use bmonn::config::EngineKind;
use bmonn::coordinator::arms::PullEngine;
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::{knn_batch_points_dense_opts, BatchOptions,
                              KnnResult, SpecStats};
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::build_host_engine;
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::remote::{spawn_loopback_ring, RemoteEngine,
                             RingClient};
use bmonn::util::rng::Rng;

/// The matrix workload: several uniform 32-pull rounds fit under the
/// 192-coordinate cap after the 32-pull init wave, so cross-round
/// speculation has rounds to predict (the default 256-pull rounds
/// would cap every arm straight after init and leave speculation
/// nothing to do).
fn workload() -> (DenseDataset, Vec<usize>, BanditParams) {
    let ds = synthetic::image_like(150, 192, 55);
    let points: Vec<usize> = (0..12).map(|i| i * 11 % 150).collect();
    let mut params = BanditParams { k: 3, ..Default::default() };
    params.policy.round_pulls = 32;
    (ds, points, params)
}

/// One batched run under a fresh seed-56 rng stream, returning the
/// results plus speculation counters and the caller's Counter charge.
fn run<E: PullEngine>(ds: &DenseDataset, points: &[usize],
                      params: &BanditParams, engine: &mut E,
                      speculate: bool)
                      -> (Vec<KnnResult>, SpecStats, u64) {
    let mut rng = Rng::new(56);
    let mut c = Counter::new();
    let opts = BatchOptions { deadline: None, speculate };
    let (res, spec) = knn_batch_points_dense_opts(
        ds, points, Metric::L2Sq, params, engine, &mut rng, &mut c,
        opts);
    (res, spec, c.get())
}

fn assert_bitwise(tag: &str, base: &[KnnResult], got: &[KnnResult]) {
    assert_eq!(base.len(), got.len(), "{tag}: result count diverged");
    for (b, g) in base.iter().zip(got) {
        assert_eq!(b.ids, g.ids, "{tag}: ids diverged");
        assert_eq!(b.dists, g.dists, "{tag}: dists diverged");
    }
}

#[test]
fn blocking_substrates_answer_identically_and_never_speculate() {
    let (ds, points, params) = workload();
    let mut solo = NativeEngine::default();
    let (base, base_spec, base_units) =
        run(&ds, &points, &params, &mut solo, false);
    assert_eq!(base_spec, SpecStats::default(),
               "speculation off must leave all counters at zero");
    // solo with the flag raised: NativeEngine is blocking, so the flag
    // must be inert — same answers, same units, zero counters
    let mut solo_on = NativeEngine::default();
    let (got, spec, units) =
        run(&ds, &points, &params, &mut solo_on, true);
    assert_bitwise("solo speculate=on", &base, &got);
    assert_eq!(units, base_units, "solo speculate=on: units diverged");
    assert_eq!(spec, SpecStats::default(),
               "a blocking engine must never speculate");
    // locally sharded engines, off and on
    for shards in [2usize, 3] {
        for speculate in [false, true] {
            let mut engine = build_host_engine(
                EngineKind::Native, shards, &[], false,
                KernelChoice::Auto, false, false, None)
                .unwrap();
            let (got, spec, units) =
                run(&ds, &points, &params, &mut engine, speculate);
            let tag = format!("sharded={shards} speculate={speculate}");
            assert_bitwise(&tag, &base, &got);
            assert_eq!(units, base_units, "{tag}: units diverged");
            assert_eq!(spec, SpecStats::default(),
                       "{tag}: local shard pools are blocking — the \
                        flag must be inert");
        }
    }
}

#[test]
fn remote_rings_answer_identically_with_speculation_off_and_on() {
    let (ds, points, params) = workload();
    let mut solo = NativeEngine::default();
    let (base, _, base_units) =
        run(&ds, &points, &params, &mut solo, false);
    for shards in [2usize, 3] {
        let (_servers, endpoints) =
            spawn_loopback_ring(&ds, shards).unwrap();
        // off: the pipelined engine must not speculate uninvited
        let mut engine = RemoteEngine::connect(&endpoints).unwrap();
        let (got, spec, units) =
            run(&ds, &points, &params, &mut engine, false);
        assert_bitwise(&format!("ring={shards} speculate=off"), &base,
                       &got);
        assert_eq!(units, base_units,
                   "ring={shards} speculate=off: units diverged");
        assert_eq!(spec, SpecStats::default(),
                   "ring={shards}: speculation off must leave all \
                    counters at zero");
        // on: bitwise-identical answers, real confirmed overlap, and
        // the accounting invariant
        let mut engine = RemoteEngine::connect(&endpoints).unwrap();
        let (got, spec, units) =
            run(&ds, &points, &params, &mut engine, true);
        assert_bitwise(&format!("ring={shards} speculate=on"), &base,
                       &got);
        assert_eq!(units, base_units,
                   "ring={shards} speculate=on: speculative waves must \
                    never bill the caller's Counter");
        assert!(spec.speculated > 0,
                "ring={shards}: the workload has uniform rounds to \
                 predict, yet nothing was speculated");
        assert!(spec.confirmed > 0,
                "ring={shards}: no speculated pull was ever confirmed \
                 — the overlap path never engaged ({spec:?})");
        assert_eq!(spec.speculated, spec.confirmed + spec.discarded,
                   "ring={shards}: speculation accounting broke \
                    ({spec:?})");
    }
}

#[test]
fn multiplexed_engines_sharing_one_client_stay_bitwise_under_speculation()
{
    let (ds, points, params) = workload();
    let mut solo = NativeEngine::default();
    let (base, _, base_units) =
        run(&ds, &points, &params, &mut solo, false);
    let (_servers, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let client = Arc::new(RingClient::connect(&endpoints).unwrap());
    // back-to-back: a speculating engine and a non-speculating engine
    // run over the same per-shard connections — abandoned speculative
    // waves from the first must never corrupt the second's demux
    let mut eng_on = RemoteEngine::from_client(client.clone());
    let mut eng_off = RemoteEngine::from_client(client.clone());
    let (got_on, spec_on, units_on) =
        run(&ds, &points, &params, &mut eng_on, true);
    let (got_off, spec_off, units_off) =
        run(&ds, &points, &params, &mut eng_off, false);
    assert_bitwise("multiplexed speculate=on", &base, &got_on);
    assert_bitwise("multiplexed speculate=off", &base, &got_off);
    assert_eq!(units_on, base_units);
    assert_eq!(units_off, base_units);
    assert!(spec_on.confirmed > 0,
            "multiplexed: speculation never confirmed ({spec_on:?})");
    assert_eq!(spec_on.speculated,
               spec_on.confirmed + spec_on.discarded);
    assert_eq!(spec_off, SpecStats::default());
    // concurrent: both drivers speculate at once on the shared client —
    // interleaved tagged waves (including abandoned ones) must leave
    // both answer streams bitwise-intact
    let (res_a, res_b) = std::thread::scope(|sc| {
        let spawn_driver = || {
            let client = client.clone();
            let (ds, points, params) = (&ds, &points, &params);
            sc.spawn(move || {
                let mut engine = RemoteEngine::from_client(client);
                run(ds, points, params, &mut engine, true)
            })
        };
        let ha = spawn_driver();
        let hb = spawn_driver();
        (ha.join().unwrap(), hb.join().unwrap())
    });
    for (tag, (got, spec, units)) in
        [("concurrent driver A", &res_a), ("concurrent driver B", &res_b)]
    {
        assert_bitwise(tag, &base, got);
        assert_eq!(*units, base_units, "{tag}: units diverged");
        assert_eq!(spec.speculated, spec.confirmed + spec.discarded,
                   "{tag}: speculation accounting broke ({spec:?})");
    }
}
