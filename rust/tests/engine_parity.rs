//! Engine parity: `ScalarEngine` (the semantic reference) vs
//! `NativeEngine` (unrolled f32 hot path) must agree within 1e-5
//! relative error on pull estimates and exact distances, across both
//! metrics, across the kernels' unroll/block boundaries, and through the
//! new coalesced multi-query `pull_batch` path.

use bmonn::coordinator::arms::{PullEngine, PullRequest, ScalarEngine};
use bmonn::data::{synthetic, Metric};
use bmonn::prop_assert;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::proptest;
use bmonn::util::rng::Rng;

const REL_TOL: f64 = 1e-5;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1.0)
}

/// t values straddling the 4-way unrolls (both l2 and l1) and the
/// larger pull sizes the batched policy issues.
const PULL_SIZES: &[usize] = &[1, 2, 3, 4, 5, 7, 8, 9, 31, 32, 33, 255,
                               256];

#[test]
fn partial_sums_parity_across_block_boundaries() {
    let d = 300;
    let n = 12;
    let ds = synthetic::gaussian_iid(n, d, 71);
    let mut rng = Rng::new(72);
    let query: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..n as u32).collect();
    for &t in PULL_SIZES {
        let coords: Vec<u32> =
            (0..t).map(|_| rng.below(d) as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut scalar = ScalarEngine;
            let mut native = NativeEngine::default();
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            scalar.partial_sums(&ds, &query, &rows, &coords, metric,
                                &mut s1, &mut q1);
            native.partial_sums(&ds, &query, &rows, &coords, metric,
                                &mut s2, &mut q2);
            for i in 0..n {
                // compare per-pull estimates (sum/t), the quantity the
                // bandit actually consumes
                let td = t as f64;
                assert!(close(s1[i] / td, s2[i] / td),
                        "{metric:?} t={t} row {i} mean: {} vs {}",
                        s1[i] / td, s2[i] / td);
                assert!(close(q1[i] / td, q2[i] / td),
                        "{metric:?} t={t} row {i} sq-mean: {} vs {}",
                        q1[i] / td, q2[i] / td);
            }
        }
    }
}

#[test]
fn exact_dists_parity_across_dims() {
    // dims straddling the 8-way unroll of the exact kernels
    for &d in &[1usize, 2, 7, 8, 9, 15, 16, 17, 63, 64, 65, 200, 256] {
        let n = 6;
        let ds = synthetic::gaussian_iid(n, d, 73 + d as u64);
        let mut rng = Rng::new(74);
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> = (0..n as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut e1 = Vec::new();
            let mut e2 = Vec::new();
            ScalarEngine.exact_dists(&ds, &query, &rows, metric, &mut e1);
            NativeEngine::default().exact_dists(&ds, &query, &rows, metric,
                                                &mut e2);
            for i in 0..n {
                assert!(close(e1[i], e2[i]),
                        "{metric:?} d={d} row {i}: {} vs {}", e1[i], e2[i]);
            }
        }
    }
}

#[test]
fn multi_query_pull_batch_parity() {
    // the coalesced path: scalar's reference pull_batch (per-request
    // partial_sums) vs native's row-major swept implementation
    proptest::check(25, |rng| {
        let n = 2 + rng.below(24);
        let d = 4 + rng.below(200);
        let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
        let n_reqs = 1 + rng.below(6);
        let queries: Vec<Vec<f32>> = (0..n_reqs)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let rowsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let m = 1 + rng.below(n);
                (0..m).map(|_| rng.below(n) as u32).collect()
            })
            .collect();
        let coordsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let t = PULL_SIZES[rng.below(PULL_SIZES.len())];
                (0..t).map(|_| rng.below(d) as u32).collect()
            })
            .collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let reqs: Vec<PullRequest> = (0..n_reqs)
                .map(|i| PullRequest {
                    query: &queries[i],
                    rows: &rowsets[i],
                    coord_ids: &coordsets[i],
                })
                .collect();
            let mut scalar = ScalarEngine;
            let mut native = NativeEngine::default();
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            scalar.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
            native.pull_batch(&ds, &reqs, metric, &mut s2, &mut q2);
            prop_assert!(s1.len() == s2.len() && q1.len() == q2.len(),
                         "output shape mismatch");
            let mut off = 0usize;
            for (ri, r) in reqs.iter().enumerate() {
                let t = r.coord_ids.len() as f64;
                for j in 0..r.rows.len() {
                    let i = off + j;
                    prop_assert!(
                        close(s1[i] / t, s2[i] / t),
                        "{metric:?} req {ri} row {j} mean: {} vs {}",
                        s1[i] / t, s2[i] / t
                    );
                    prop_assert!(
                        close(q1[i] / t, q2[i] / t),
                        "{metric:?} req {ri} row {j} sq-mean: {} vs {}",
                        q1[i] / t, q2[i] / t
                    );
                }
                off += r.rows.len();
            }
        }
        Ok(())
    });
}
