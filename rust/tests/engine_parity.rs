//! Engine parity: `ScalarEngine` (the semantic reference) vs
//! `NativeEngine` (unrolled f32 hot path) must agree within 1e-5
//! relative error on pull estimates and exact distances, across both
//! metrics, across the kernels' unroll/block boundaries, and through the
//! new coalesced multi-query `pull_batch` path. The same tolerance pins
//! every runtime-dispatched SIMD kernel tier to the forced-scalar tier,
//! and the opt-in quantized sampling tier to the PAC guarantee.

use bmonn::coordinator::arms::{PullEngine, PullRequest, ScalarEngine};
use bmonn::coordinator::bandit::{BanditParams, PullPolicy};
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::pac::{is_eps_correct, pac_knn_point_dense};
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::prop_assert;
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::proptest;
use bmonn::util::rng::Rng;

const REL_TOL: f64 = 1e-5;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1.0)
}

/// t values straddling the 4-way unrolls (both l2 and l1) and the
/// larger pull sizes the batched policy issues.
const PULL_SIZES: &[usize] = &[1, 2, 3, 4, 5, 7, 8, 9, 31, 32, 33, 255,
                               256];

#[test]
fn partial_sums_parity_across_block_boundaries() {
    let d = 300;
    let n = 12;
    let ds = synthetic::gaussian_iid(n, d, 71);
    let mut rng = Rng::new(72);
    let query: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..n as u32).collect();
    for &t in PULL_SIZES {
        let coords: Vec<u32> =
            (0..t).map(|_| rng.below(d) as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut scalar = ScalarEngine;
            let mut native = NativeEngine::default();
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            scalar.partial_sums(&ds, &query, &rows, &coords, metric,
                                &mut s1, &mut q1);
            native.partial_sums(&ds, &query, &rows, &coords, metric,
                                &mut s2, &mut q2);
            for i in 0..n {
                // compare per-pull estimates (sum/t), the quantity the
                // bandit actually consumes
                let td = t as f64;
                assert!(close(s1[i] / td, s2[i] / td),
                        "{metric:?} t={t} row {i} mean: {} vs {}",
                        s1[i] / td, s2[i] / td);
                assert!(close(q1[i] / td, q2[i] / td),
                        "{metric:?} t={t} row {i} sq-mean: {} vs {}",
                        q1[i] / td, q2[i] / td);
            }
        }
    }
}

#[test]
fn exact_dists_parity_across_dims() {
    // dims straddling the 8-way unroll of the exact kernels
    for &d in &[1usize, 2, 7, 8, 9, 15, 16, 17, 63, 64, 65, 200, 256] {
        let n = 6;
        let ds = synthetic::gaussian_iid(n, d, 73 + d as u64);
        let mut rng = Rng::new(74);
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> = (0..n as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut e1 = Vec::new();
            let mut e2 = Vec::new();
            ScalarEngine.exact_dists(&ds, &query, &rows, metric, &mut e1);
            NativeEngine::default().exact_dists(&ds, &query, &rows, metric,
                                                &mut e2);
            for i in 0..n {
                assert!(close(e1[i], e2[i]),
                        "{metric:?} d={d} row {i}: {} vs {}", e1[i], e2[i]);
            }
        }
    }
}

#[test]
fn multi_query_pull_batch_parity() {
    // the coalesced path: scalar's reference pull_batch (per-request
    // partial_sums) vs native's row-major swept implementation
    proptest::check(25, |rng| {
        let n = 2 + rng.below(24);
        let d = 4 + rng.below(200);
        let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
        let n_reqs = 1 + rng.below(6);
        let queries: Vec<Vec<f32>> = (0..n_reqs)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let rowsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let m = 1 + rng.below(n);
                (0..m).map(|_| rng.below(n) as u32).collect()
            })
            .collect();
        let coordsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let t = PULL_SIZES[rng.below(PULL_SIZES.len())];
                (0..t).map(|_| rng.below(d) as u32).collect()
            })
            .collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let reqs: Vec<PullRequest> = (0..n_reqs)
                .map(|i| PullRequest {
                    query: &queries[i],
                    rows: &rowsets[i],
                    coord_ids: &coordsets[i],
                })
                .collect();
            let mut scalar = ScalarEngine;
            let mut native = NativeEngine::default();
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            scalar.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
            native.pull_batch(&ds, &reqs, metric, &mut s2, &mut q2);
            prop_assert!(s1.len() == s2.len() && q1.len() == q2.len(),
                         "output shape mismatch");
            let mut off = 0usize;
            for (ri, r) in reqs.iter().enumerate() {
                let t = r.coord_ids.len() as f64;
                for j in 0..r.rows.len() {
                    let i = off + j;
                    prop_assert!(
                        close(s1[i] / t, s2[i] / t),
                        "{metric:?} req {ri} row {j} mean: {} vs {}",
                        s1[i] / t, s2[i] / t
                    );
                    prop_assert!(
                        close(q1[i] / t, q2[i] / t),
                        "{metric:?} req {ri} row {j} sq-mean: {} vs {}",
                        q1[i] / t, q2[i] / t
                    );
                }
                off += r.rows.len();
            }
        }
        Ok(())
    });
}

/// Every SIMD tier this host can run, forced explicitly, must agree
/// with the forced-scalar tier within the same tolerance the scalar
/// engine is held to — across lengths straddling every SIMD register
/// width (NEON sweeps 4 f32 lanes, AVX2 sweeps 8) plus their remainder
/// tails of 1..width-1 elements.
#[test]
fn forced_kernel_tiers_match_forced_scalar() {
    let forced = [KernelChoice::Avx2, KernelChoice::Neon];
    let mut tested = 0;
    for choice in forced {
        let mut simd = match NativeEngine::with_options(choice, false) {
            Ok(e) => e,
            Err(_) => continue, // tier not available on this host
        };
        tested += 1;
        let mut scalar =
            NativeEngine::with_options(KernelChoice::Scalar, false)
                .expect("scalar tier is always available");

        // exact_dists: dims around the 4- and 8-lane widths and a
        // larger dim exercising the main loop plus a tail
        for &d in &[1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32,
                    33, 200] {
            let n = 8;
            let ds = synthetic::gaussian_iid(n, d, 91 + d as u64);
            let mut rng = Rng::new(92);
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32).collect();
            let rows: Vec<u32> = (0..n as u32).collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let (mut e1, mut e2) = (Vec::new(), Vec::new());
                scalar.exact_dists(&ds, &query, &rows, metric, &mut e1);
                simd.exact_dists(&ds, &query, &rows, metric, &mut e2);
                for i in 0..n {
                    assert!(close(e1[i], e2[i]),
                            "{choice:?} {metric:?} d={d} row {i}: {} \
                             vs {}", e1[i], e2[i]);
                }
            }
        }

        // partial_sums: pull sizes around the same lane boundaries
        let d = 256;
        let n = 10;
        let ds = synthetic::gaussian_iid(n, d, 93);
        let mut rng = Rng::new(94);
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> = (0..n as u32).collect();
        for &t in PULL_SIZES {
            let coords: Vec<u32> =
                (0..t).map(|_| rng.below(d) as u32).collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                let (mut s2, mut q2) = (Vec::new(), Vec::new());
                scalar.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s1, &mut q1);
                simd.partial_sums(&ds, &query, &rows, &coords, metric,
                                  &mut s2, &mut q2);
                let td = t as f64;
                for i in 0..n {
                    assert!(close(s1[i] / td, s2[i] / td),
                            "{choice:?} {metric:?} t={t} row {i} mean: \
                             {} vs {}", s1[i] / td, s2[i] / td);
                    assert!(close(q1[i] / td, q2[i] / td),
                            "{choice:?} {metric:?} t={t} row {i} \
                             sq-mean: {} vs {}", q1[i] / td, q2[i] / td);
                }
            }
        }
    }
    // the auto tier always constructs, whatever this host supports —
    // and on a scalar-only host the loop above legitimately tests
    // nothing, so make that explicit rather than silently green
    let auto = NativeEngine::with_options(KernelChoice::Auto, false)
        .expect("auto dispatch never fails");
    if tested == 0 {
        assert_eq!(auto.kernel_tier().as_str(), "scalar",
                   "no SIMD tier constructed yet auto dispatched one");
    }
}

/// The quantized tier must keep the PAC guarantee: candidates sampled
/// from the int8 shadow, rescored on exact f32, confidence half-widths
/// widened by the engine-reported quantization bias — so the returned
/// neighbors still satisfy θ ≤ θ_(k) + ε on the power-law-gap model.
#[test]
fn quantized_tier_keeps_pac_recall() {
    let ds = synthetic::power_law_gaps(150, 1024, 0.5, 1.0, 31);
    let mut engine = NativeEngine::with_options(KernelChoice::Auto, true)
        .expect("quantized native engine");
    // the shadow must actually engage and report a nonzero bias bound
    let mut rng = Rng::new(32);
    let probe: Vec<f32> =
        (0..ds.d).map(|_| rng.gaussian() as f32).collect();
    let bias = engine.quant_bias(&ds, &probe, Metric::L2Sq);
    assert!(bias > 0.0 && bias.is_finite(),
            "quantized engine reported bias {bias}");

    let k = 5;
    let eps = 0.3;
    let params = BanditParams { k, delta: 0.01,
                                policy: PullPolicy::batched(),
                                ..Default::default() };
    let mut c = Counter::new();
    let res = pac_knn_point_dense(&ds, 0, Metric::L2Sq, eps, &params,
                                  &mut engine, &mut rng, &mut c);
    assert_eq!(res.ids.len(), k);
    assert!(is_eps_correct(&ds, 0, Metric::L2Sq, &res, k, eps));
}

/// Exact-identification mode with the quantized tier: the widened
/// intervals make the bandit fall back to exact f32 evaluation before
/// it can separate near-ties, so the returned nearest neighbor must be
/// the true one.
#[test]
fn quantized_tier_exact_mode_finds_true_nn() {
    let ds = synthetic::power_law_gaps(120, 512, 0.5, 1.0, 41);
    let mut engine = NativeEngine::with_options(KernelChoice::Auto, true)
        .expect("quantized native engine");
    let params = BanditParams { k: 1, delta: 0.01,
                                policy: PullPolicy::batched(),
                                ..Default::default() };
    let mut rng = Rng::new(42);
    let mut c = Counter::new();
    let res = knn_point_dense(&ds, 0, Metric::L2Sq, &params, &mut engine,
                              &mut rng, &mut c);

    let mut ct = Counter::new();
    let truth = (1..ds.n)
        .min_by(|&a, &b| {
            ds.dist(0, a, Metric::L2Sq, &mut ct)
                .partial_cmp(&ds.dist(0, b, Metric::L2Sq, &mut ct))
                .unwrap()
        })
        .unwrap() as u32;
    assert_eq!(res.ids, vec![truth],
               "quantized exact mode missed the true NN");
}
