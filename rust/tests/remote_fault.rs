//! Fault injection across the network boundary: killing a shard server
//! mid-stream must surface as clean, prompt errors — never hung waiters
//! — and the coordinator must heal once the shard is back.
//!
//! Engine level: a dead shard turns the in-flight wave into a panic
//! (caught by callers) within the I/O timeout; a fresh connect after the
//! shard restarts is bitwise-correct again.
//!
//! Coordinator level: the query server's worker catches that panic,
//! answers the affected queries with error responses, and rebuilds (=
//! reconnects) its engine — extending the PR 2 in-process
//! worker-survival guarantee across the wire. While the ring is down,
//! queries get `engine unavailable` errors; after the shard restarts on
//! the same endpoint, the same server answers correctly again.

use std::time::{Duration, Instant};

use bmonn::coordinator::arms::PullEngine;
use bmonn::coordinator::server::{Client, Server, ServerConfig};
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::remote::{spawn_loopback_ring, RemoteEngine,
                             ShardServer};
use bmonn::util::json::Json;

/// Rebind a shard on the endpoint it died on (the listener socket may
/// take a moment to become reusable).
fn restart_shard(addr: &str, data: &DenseDataset, shard: usize,
                 n_shards: usize) -> ShardServer {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match ShardServer::start_shard_of(addr, data, shard, n_shards) {
            Ok(srv) => return srv,
            Err(e) => {
                assert!(Instant::now() < deadline,
                        "could not rebind {addr}: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

#[test]
fn shard_death_mid_wave_panics_promptly_and_a_reconnect_recovers() {
    let ds = synthetic::gaussian_iid(64, 32, 21);
    let q = ds.row_vec(0);
    let rows: Vec<u32> = (0..64).collect();
    let coords: Vec<u32> = (0..16).collect();
    let (mut servers, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let mut engine = RemoteEngine::connect_with_timeout(
        &endpoints, Some(Duration::from_secs(5))).unwrap();
    // reference answer while the ring is healthy
    let mut solo = NativeEngine::default();
    let (mut s0, mut q0) = (Vec::new(), Vec::new());
    solo.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s0,
                      &mut q0);
    let (mut s1, mut q1) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s1,
                        &mut q1);
    assert_eq!(s0, s1);
    // kill shard 1 while waves keep flowing: some wave must fail — as a
    // caught panic, promptly — and none may hang
    let dead_endpoint = servers[1].endpoint();
    let killer = std::thread::spawn({
        let mut victim = servers.remove(1);
        move || {
            std::thread::sleep(Duration::from_millis(50));
            victim.stop();
        }
    });
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut saw_failure = false;
    while Instant::now() < deadline {
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq,
                                    &mut s, &mut sq);
                s
            }));
        match outcome {
            Ok(s) => assert_eq!(s0, s, "healthy waves must stay bitwise"),
            Err(e) => {
                let msg = e.downcast_ref::<String>().cloned()
                    .unwrap_or_default();
                assert!(msg.contains("remote pull wave failed"),
                        "unexpected panic: {msg}");
                saw_failure = true;
                break;
            }
        }
    }
    killer.join().unwrap();
    assert!(saw_failure,
            "waves kept succeeding for 20s after the shard died");
    // restart the shard on the endpoint the ring was built around; a
    // fresh connect (what the server worker's rebuild does) heals
    let _revived = restart_shard(&dead_endpoint, &ds, 1, 2);
    let mut engine = RemoteEngine::connect(&endpoints).unwrap();
    let (mut s2, mut q2) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s2,
                        &mut q2);
    assert_eq!(s0, s2, "recovered ring must be bitwise-identical again");
    assert_eq!(q0, q2);
}

#[test]
fn coordinator_answers_errors_while_a_shard_is_down_then_heals() {
    let ds = synthetic::image_like(80, 64, 99);
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1, // deterministic: one engine to break and heal
        batch_size: 4,
        remote: endpoints.clone(),
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let mut cl = Client::connect(&srv.addr).unwrap();
    // healthy round-trip through the ring
    let (ids, _, units) = cl.knn(&ds.row_vec(5), 3).unwrap();
    assert_eq!(ids[0], 5);
    assert!(units > 0);
    // kill shard 0; the in-flight engine connection dies with it
    let shard0_endpoint = ring[0].endpoint();
    ring[0].stop();
    // the next query's wave hits the dead shard: the worker catches the
    // panic and answers an error response — promptly, no hung waiter
    let t0 = Instant::now();
    let err = cl.knn(&ds.row_vec(6), 3).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(30),
            "error response must not wait on a dead peer");
    assert!(err.to_string().contains("compute panicked"),
            "got: {err}");
    // while the ring is down the worker cannot rebuild: clean errors,
    // and the connection keeps serving (ping still answers)
    let err2 = cl.knn(&ds.row_vec(7), 3).unwrap_err();
    assert!(err2.to_string().contains("engine unavailable"),
            "got: {err2}");
    let pong = cl
        .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
        .unwrap();
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
    // restart the shard on the same endpoint: the worker's lazy rebuild
    // reconnects and the very same server answers correctly again
    let _revived = restart_shard(&shard0_endpoint, &ds, 0, 2);
    let (ids, dists, units) = cl.knn(&ds.row_vec(9), 3).unwrap();
    assert_eq!(ids[0], 9, "healed ring must answer correctly");
    assert_eq!(dists.len(), 3);
    assert!(units > 0);
    // accounting stayed consistent: every query (failed ones included)
    // was counted, none lost
    let stats = cl
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap();
    assert_eq!(stats.get("queries").unwrap().as_usize(), Some(4));
    srv.stop();
}
