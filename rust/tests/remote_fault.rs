//! Fault injection across the network boundary.
//!
//! Unreplicated rings (single replica per shard): killing a shard
//! server mid-stream must surface as clean, prompt errors — never hung
//! waiters — and the coordinator must heal once the shard is back.
//! Engine level: a dead shard turns the in-flight wave into a panic
//! (caught by callers) within the I/O timeout; a fresh connect after the
//! shard restarts is bitwise-correct again. Coordinator level: the query
//! server's worker catches that panic, answers the affected queries with
//! error responses, and rebuilds (= reconnects) its engine — extending
//! the PR 2 in-process worker-survival guarantee across the wire.
//!
//! Replicated rings (`primary|replica` specs): killing any *single*
//! endpoint mid-stream must produce **no query errors at all** — the
//! sub-wave fails over to the shard's next replica and every answer
//! stays bitwise-identical to solo `NativeEngine`. A blacklisted
//! endpoint heals after a restart (the failover path reconnects to it
//! once its backoff expires). And with **every** replica of a shard
//! dead: degraded mode answers exact, coverage-annotated results over
//! the surviving rows — through the engine, the drivers and the query
//! server's JSON — while degraded-off keeps the hard-error contract.

use std::time::{Duration, Instant};

use bmonn::coordinator::arms::PullEngine;
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::server::{Client, Server, ServerConfig};
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::placement::{PlacementMap, RetryPolicy};
use bmonn::runtime::remote::{spawn_loopback_ring, RemoteEngine,
                             RemoteOptions, ShardServer};
use bmonn::util::json::Json;
use bmonn::util::rng::Rng;

/// Rebind a shard on the endpoint it died on (the listener socket may
/// take a moment to become reusable).
fn restart_shard(addr: &str, data: &DenseDataset, shard: usize,
                 n_shards: usize) -> ShardServer {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match ShardServer::start_shard_of(addr, data, shard, n_shards) {
            Ok(srv) => return srv,
            Err(e) => {
                assert!(Instant::now() < deadline,
                        "could not rebind {addr}: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

#[test]
fn shard_death_mid_wave_panics_promptly_and_a_reconnect_recovers() {
    let ds = synthetic::gaussian_iid(64, 32, 21);
    let q = ds.row_vec(0);
    let rows: Vec<u32> = (0..64).collect();
    let coords: Vec<u32> = (0..16).collect();
    let (mut servers, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let mut engine = RemoteEngine::connect_with_timeout(
        &endpoints, Some(Duration::from_secs(5))).unwrap();
    // reference answer while the ring is healthy
    let mut solo = NativeEngine::default();
    let (mut s0, mut q0) = (Vec::new(), Vec::new());
    solo.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s0,
                      &mut q0);
    let (mut s1, mut q1) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s1,
                        &mut q1);
    assert_eq!(s0, s1);
    // kill shard 1 while waves keep flowing: some wave must fail — as a
    // caught panic, promptly — and none may hang
    let dead_endpoint = servers[1].endpoint();
    let killer = std::thread::spawn({
        let mut victim = servers.remove(1);
        move || {
            std::thread::sleep(Duration::from_millis(50));
            victim.stop();
        }
    });
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut saw_failure = false;
    while Instant::now() < deadline {
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq,
                                    &mut s, &mut sq);
                s
            }));
        match outcome {
            Ok(s) => assert_eq!(s0, s, "healthy waves must stay bitwise"),
            Err(e) => {
                let msg = e.downcast_ref::<String>().cloned()
                    .unwrap_or_default();
                assert!(msg.contains("remote pull wave failed"),
                        "unexpected panic: {msg}");
                saw_failure = true;
                break;
            }
        }
    }
    killer.join().unwrap();
    assert!(saw_failure,
            "waves kept succeeding for 20s after the shard died");
    // restart the shard on the endpoint the ring was built around; a
    // fresh connect (what the server worker's rebuild does) heals
    let _revived = restart_shard(&dead_endpoint, &ds, 1, 2);
    let mut engine = RemoteEngine::connect(&endpoints).unwrap();
    let (mut s2, mut q2) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s2,
                        &mut q2);
    assert_eq!(s0, s2, "recovered ring must be bitwise-identical again");
    assert_eq!(q0, q2);
}

#[test]
fn coordinator_answers_errors_while_a_shard_is_down_then_heals() {
    let ds = synthetic::image_like(80, 64, 99);
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1, // deterministic: one engine to break and heal
        batch_size: 4,
        remote: endpoints.clone(),
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let mut cl = Client::connect(&srv.addr).unwrap();
    // healthy round-trip through the ring
    let (ids, _, units) = cl.knn(&ds.row_vec(5), 3).unwrap();
    assert_eq!(ids[0], 5);
    assert!(units > 0);
    // kill shard 0; the in-flight engine connection dies with it
    let shard0_endpoint = ring[0].endpoint();
    ring[0].stop();
    // the next query's wave hits the dead shard: the worker catches the
    // panic and answers an error response — promptly, no hung waiter
    let t0 = Instant::now();
    let err = cl.knn(&ds.row_vec(6), 3).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(30),
            "error response must not wait on a dead peer");
    assert!(err.to_string().contains("compute panicked"),
            "got: {err}");
    // while the ring is down the worker cannot rebuild: clean errors,
    // and the connection keeps serving (ping still answers)
    let err2 = cl.knn(&ds.row_vec(7), 3).unwrap_err();
    assert!(err2.to_string().contains("engine unavailable"),
            "got: {err2}");
    let pong = cl
        .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
        .unwrap();
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
    // restart the shard on the same endpoint: the worker's lazy rebuild
    // reconnects and the very same server answers correctly again
    let _revived = restart_shard(&shard0_endpoint, &ds, 0, 2);
    let (ids, dists, units) = cl.knn(&ds.row_vec(9), 3).unwrap();
    assert_eq!(ids[0], 9, "healed ring must answer correctly");
    assert_eq!(dists.len(), 3);
    assert!(units > 0);
    // accounting stayed consistent: every query (failed ones included)
    // was counted, none lost
    let stats = cl
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap();
    assert_eq!(stats.get("queries").unwrap().as_usize(), Some(4));
    srv.stop();
}

/// Build `primary|replica` specs for a 2×2 replicated loopback ring.
fn replicated_specs(p_eps: &[String], r_eps: &[String]) -> Vec<String> {
    p_eps
        .iter()
        .zip(r_eps)
        .map(|(p, r)| format!("{p}|{r}"))
        .collect()
}

/// Fast-backoff options so the tests never sit out long blacklists.
fn fast_opts(degraded: bool) -> RemoteOptions {
    RemoteOptions {
        timeout: Some(Duration::from_secs(5)),
        degraded,
        retry: RetryPolicy {
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_millis(200),
        },
        ..RemoteOptions::default()
    }
}

#[test]
fn killing_any_single_endpoint_mid_stream_yields_no_errors_bitwise() {
    let ds = synthetic::gaussian_iid(64, 32, 51);
    let q = ds.row_vec(0);
    let rows: Vec<u32> = (0..64).collect();
    let coords: Vec<u32> = (0..16).collect();
    let (mut primaries, p_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let (_replicas, r_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let specs = replicated_specs(&p_eps, &r_eps);
    let mut engine = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(), fast_opts(false)).unwrap();
    let mut solo = NativeEngine::default();
    let (mut s0, mut q0) = (Vec::new(), Vec::new());
    solo.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s0,
                      &mut q0);
    // kill shard 1's primary while waves keep flowing: EVERY wave must
    // succeed — the sub-wave fails over to the replica mid-stream — and
    // every answer must stay bitwise-identical to solo execution
    let killer = std::thread::spawn({
        let mut victim = primaries.remove(1);
        move || {
            std::thread::sleep(Duration::from_millis(50));
            victim.stop();
        }
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut waves = 0u32;
    while Instant::now() < deadline && waves < 400 {
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s,
                            &mut sq);
        assert_eq!(s0, s, "wave {waves} diverged after the kill");
        assert_eq!(q0, sq);
        waves += 1;
    }
    killer.join().unwrap();
    assert!(waves >= 10, "only {waves} waves ran — kill raced the test");
    // the other wave kinds ride the same failover path
    let mut exact_solo = Vec::new();
    let mut exact_remote = Vec::new();
    solo.exact_dists(&ds, &q, &rows, Metric::L1, &mut exact_solo);
    engine.exact_dists(&ds, &q, &rows, Metric::L1, &mut exact_remote);
    assert_eq!(exact_solo, exact_remote);
    // now kill shard 0's primary too (a different single endpoint, mid
    // stream): the replicas alone must carry the whole ring, bitwise
    drop(primaries);
    let (mut s, mut sq) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s,
                        &mut sq);
    assert_eq!(s0, s, "replicas-only ring must stay bitwise");
    assert_eq!(q0, sq);
}

#[test]
fn blacklisted_primary_heals_after_restart() {
    let ds = synthetic::gaussian_iid(40, 16, 91);
    let (mut primaries, p_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let (mut replicas, r_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let specs = replicated_specs(&p_eps, &r_eps);
    let mut engine = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(), fast_opts(false)).unwrap();
    let q = ds.row_vec(1);
    let rows: Vec<u32> = (0..40).collect();
    let coords: Vec<u32> = (0..8).collect();
    let mut solo = NativeEngine::default();
    let (mut s0, mut q0) = (Vec::new(), Vec::new());
    solo.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s0,
                      &mut q0);
    // kill shard 0's primary: the wave fails over to the replica and
    // the primary goes on the blacklist
    let p0_endpoint = primaries[0].endpoint();
    primaries[0].stop();
    let (mut s, mut sq) = (Vec::new(), Vec::new());
    engine.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut s,
                        &mut sq);
    assert_eq!(s0, s, "failover wave must stay bitwise");
    // restart the primary on its old endpoint, then kill the replica:
    // waves must return to the *healed* primary — the blacklist must
    // not exclude it forever (its backoff expires, the reconnect heals)
    let _revived = restart_shard(&p0_endpoint, &ds, 0, 2);
    replicas[0].stop();
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let outcome = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                engine.partial_sums(&ds, &q, &rows, &coords,
                                    Metric::L2Sq, &mut s, &mut sq);
                (s, sq)
            }));
        match outcome {
            Ok((s, sq)) => {
                assert_eq!(s0, s, "healed primary must answer bitwise");
                assert_eq!(q0, sq);
                break;
            }
            Err(_) => {
                // both endpoints momentarily blacklisted — retry until
                // the primary's backoff expires and it heals
                assert!(Instant::now() < deadline,
                        "ring never healed back onto the restarted \
                         primary");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

#[test]
fn dead_shard_degrades_with_coverage_when_opted_in_and_panics_otherwise() {
    let ds = synthetic::image_like(60, 32, 77);
    let k = 3;
    let params = BanditParams { k, delta: 0.01, ..Default::default() };
    // --- degraded OFF: hard error once the shard's only replica dies --
    {
        let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
        let mut eng = RemoteEngine::connect_opts(
            &PlacementMap::parse(&endpoints).unwrap(), fast_opts(false))
            .unwrap();
        ring[1].stop();
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(3);
                let mut c = Counter::new();
                knn_point_dense(&ds, 5, Metric::L2Sq, &params, &mut eng,
                                &mut rng, &mut c)
            }))
            .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("remote pull wave failed")
                    || msg.contains("remote exact wave failed"),
                "degraded-off must keep the hard-error contract: {msg}");
    }
    // --- degraded ON: coverage-annotated exact answers over survivors -
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let mut eng = RemoteEngine::connect_opts(
        &PlacementMap::parse(&endpoints).unwrap(), fast_opts(true))
        .unwrap();
    // healthy: full coverage, the bandit path runs, answers are bitwise
    // equal to solo native execution under the same rng stream
    assert_eq!(eng.coverage(), None, "healthy ring must not degrade");
    let res = {
        let mut rng = Rng::new(7);
        let mut c = Counter::new();
        knn_point_dense(&ds, 5, Metric::L2Sq, &params, &mut eng, &mut rng,
                        &mut c)
    };
    assert!(res.coverage.is_none());
    let solo_res = {
        let mut solo = NativeEngine::default();
        let mut rng = Rng::new(7);
        let mut c = Counter::new();
        knn_point_dense(&ds, 5, Metric::L2Sq, &params, &mut solo,
                        &mut rng, &mut c)
    };
    assert_eq!(res.ids, solo_res.ids);
    assert_eq!(res.dists, solo_res.dists);
    // kill shard 1 (rows [30, 60)): queries must still ANSWER — exact
    // top-k over the surviving rows with an explicit coverage annotation
    ring[1].stop();
    let res = {
        let mut rng = Rng::new(8);
        let mut c = Counter::new();
        knn_point_dense(&ds, 5, Metric::L2Sq, &params, &mut eng, &mut rng,
                        &mut c)
    };
    let cov = res.coverage.as_ref().expect("degraded answer must carry \
                                            its coverage");
    assert_eq!(cov.rows_total, 60);
    assert_eq!(cov.rows_live(), 30);
    assert_eq!(cov.live, vec![(0, 30)]);
    assert!((cov.fraction() - 0.5).abs() < 1e-12);
    assert_eq!(res.ids.len(), k);
    assert!(res.ids.iter().all(|&r| r < 30),
            "degraded ids must come from surviving rows: {:?}", res.ids);
    // and they are exactly the top-k over surviving rows, computed with
    // the same native exact kernel the shard servers run (bitwise)
    let cand_rows: Vec<u32> = (0..30u32).filter(|&r| r != 5).collect();
    let mut dvals = Vec::new();
    {
        let mut solo = NativeEngine::default();
        solo.exact_dists(&ds, &ds.row_vec(5), &cand_rows, Metric::L2Sq,
                         &mut dvals);
    }
    let mut cands: Vec<(f64, u32)> =
        dvals.iter().copied().zip(cand_rows.iter().copied()).collect();
    cands.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let want_ids: Vec<u32> = cands[..k].iter().map(|&(_, r)| r).collect();
    let want_dists: Vec<f64> = cands[..k].iter().map(|&(d, _)| d).collect();
    assert_eq!(res.ids, want_ids);
    assert_eq!(res.dists, want_dists);
    // shard restored: coverage returns to full and the bandit path is
    // bitwise again (the probe reconnects past the healed blacklist)
    let restored = restart_shard(&ring[1].endpoint(), &ds, 1, 2);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if eng.coverage().is_none() {
            break;
        }
        assert!(Instant::now() < deadline,
                "coverage never healed after the shard restart");
        std::thread::sleep(Duration::from_millis(50));
    }
    let res = {
        let mut rng = Rng::new(7);
        let mut c = Counter::new();
        knn_point_dense(&ds, 5, Metric::L2Sq, &params, &mut eng, &mut rng,
                        &mut c)
    };
    assert!(res.coverage.is_none());
    assert_eq!(res.ids, solo_res.ids);
    assert_eq!(res.dists, solo_res.dists);
    drop(restored);
}

#[test]
fn coordinator_answers_degraded_queries_with_coverage_fields() {
    let ds = synthetic::image_like(80, 64, 123);
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints.clone(),
        degraded: true,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let mut cl = Client::connect(&srv.addr).unwrap();
    let knn_req = |row: usize| {
        Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(&ds.row_vec(row))),
            ("k", Json::Num(3.0)),
        ])
    };
    // healthy ring: plain full answers, no coverage fields
    let resp = cl.request(&knn_req(5)).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    assert!(resp.get("coverage").is_none(),
            "full answers must not be annotated");
    // kill shard 0 (rows [0, 40)): the very next query must *answer*,
    // over the surviving rows, with the coverage annotation — no error
    // response at all (that is the degraded contract)
    ring[0].stop();
    let resp = cl.request(&knn_req(50)).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)),
               "degraded query must succeed: {resp:?}");
    let frac = resp.get("coverage").and_then(|v| v.as_f64()).unwrap();
    assert!((frac - 0.5).abs() < 1e-9, "coverage {frac}");
    assert_eq!(resp.get("rows_live").and_then(|v| v.as_usize()), Some(40));
    assert_eq!(resp.get("rows_total").and_then(|v| v.as_usize()),
               Some(80));
    let ids: Vec<usize> = resp
        .get("ids")
        .and_then(|a| a.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap();
    assert_eq!(ids.len(), 3);
    assert!(ids.iter().all(|&r| (40..80).contains(&r)),
            "degraded ids must come from the surviving shard: {ids:?}");
    // stats: both queries counted, none lost
    let stats = cl
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap();
    assert_eq!(stats.get("queries").unwrap().as_usize(), Some(2));
    srv.stop();
}
