//! Network determinism: `RemoteEngine` over a ring of in-process
//! loopback shard servers must be **bitwise** identical to a
//! single-threaded `NativeEngine` for every ring size — including uneven
//! splits, servers owning zero rows (n < S), and empty requests — across
//! `partial_sums`, `exact_dists` and the coalesced `pull_batch` path,
//! and end-to-end through the batched k-NN driver. Mirrors
//! `tests/sharded_parity.rs` case-for-case: both substrates plan waves
//! with the same `runtime::partition` splitter, and the wire moves float
//! bits verbatim, so the distributed answer is the local answer.

use bmonn::coordinator::arms::{PullEngine, PullRequest};
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::knn_batch_points_dense;
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::remote::{spawn_loopback_ring, RemoteEngine,
                             ShardServer};
use bmonn::util::rng::Rng;

/// Dataset sizes that produce uneven splits, zero-row shard servers
/// (n < S for the larger ring sizes), and exact divisions.
const SIZES: &[usize] = &[3, 5, 8, 16, 33];

fn ring(data: &DenseDataset, shards: usize)
        -> (Vec<ShardServer>, RemoteEngine) {
    let (servers, endpoints) = spawn_loopback_ring(data, shards).unwrap();
    let engine = RemoteEngine::connect(&endpoints).unwrap();
    (servers, engine)
}

#[test]
fn partial_sums_and_exact_dists_bitwise_over_loopback_rings() {
    for &n in SIZES {
        let d = 40;
        let ds = synthetic::gaussian_iid(n, d, 1000 + n as u64);
        let mut rng = Rng::new(n as u64);
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        // duplicate and out-of-order rows are legal pull targets
        let rows: Vec<u32> = (0..3 * n)
            .map(|_| rng.below(n) as u32)
            .collect();
        let coords: Vec<u32> =
            (0..17).map(|_| rng.below(d) as u32).collect();
        for shards in 1..=3usize {
            let (_servers, mut remote) = ring(&ds, shards);
            for metric in [Metric::L2Sq, Metric::L1] {
                let mut solo = NativeEngine::default();
                let (mut s0, mut q0) = (Vec::new(), Vec::new());
                solo.partial_sums(&ds, &query, &rows, &coords, metric,
                                  &mut s0, &mut q0);
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                remote.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s1, &mut q1);
                assert_eq!(s0, s1, "sums n={n} ring={shards} {metric:?}");
                assert_eq!(q0, q1, "sqs n={n} ring={shards} {metric:?}");
                let mut e0 = Vec::new();
                solo.exact_dists(&ds, &query, &rows, metric, &mut e0);
                let mut e1 = Vec::new();
                remote.exact_dists(&ds, &query, &rows, metric, &mut e1);
                assert_eq!(e0, e1, "exact n={n} ring={shards} {metric:?}");
            }
        }
    }
}

#[test]
fn pull_batch_bitwise_over_loopback_rings() {
    for &n in SIZES {
        let d = 64;
        let ds = synthetic::gaussian_iid(n, d, 2000 + n as u64);
        let mut rng = Rng::new(77 + n as u64);
        let n_reqs = 4;
        let queries: Vec<Vec<f32>> = (0..n_reqs)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let rowsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|i| {
                // one empty request exercises the zero-length range path
                let m = if i == 2 { 0 } else { 1 + rng.below(2 * n) };
                (0..m).map(|_| rng.below(n) as u32).collect()
            })
            .collect();
        let coordsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let t = 1 + rng.below(40);
                (0..t).map(|_| rng.below(d) as u32).collect()
            })
            .collect();
        for shards in 1..=3usize {
            let (_servers, mut remote) = ring(&ds, shards);
            for metric in [Metric::L2Sq, Metric::L1] {
                let reqs: Vec<PullRequest> = (0..n_reqs)
                    .map(|i| PullRequest {
                        query: &queries[i],
                        rows: &rowsets[i],
                        coord_ids: &coordsets[i],
                    })
                    .collect();
                let mut solo = NativeEngine::default();
                let (mut s0, mut q0) = (Vec::new(), Vec::new());
                solo.pull_batch(&ds, &reqs, metric, &mut s0, &mut q0);
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                remote.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
                assert_eq!(s0, s1,
                           "pull sums n={n} ring={shards} {metric:?}");
                assert_eq!(q0, q1,
                           "pull sqs n={n} ring={shards} {metric:?}");
            }
        }
    }
}

#[test]
fn big_pull_batch_wave_fans_out_concurrently_bitwise() {
    // waves large enough that every server gets real work and the client
    // fans sub-waves out on concurrent I/O threads: 16 requests over all
    // rows with 256 coords each is ~1M coordinate ops per wave
    let n = 256;
    let d = 128;
    let ds = synthetic::gaussian_iid(n, d, 9);
    let mut rng = Rng::new(10);
    let n_reqs = 16;
    let queries: Vec<Vec<f32>> = (0..n_reqs)
        .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
        .collect();
    let rows_all: Vec<u32> = (0..n as u32).collect();
    let coordsets: Vec<Vec<u32>> = (0..n_reqs)
        .map(|_| (0..256).map(|_| rng.below(d) as u32).collect())
        .collect();
    for shards in [2usize, 3] {
        let (_servers, mut remote) = ring(&ds, shards);
        for metric in [Metric::L2Sq, Metric::L1] {
            let reqs: Vec<PullRequest> = (0..n_reqs)
                .map(|i| PullRequest {
                    query: &queries[i],
                    rows: &rows_all,
                    coord_ids: &coordsets[i],
                })
                .collect();
            let mut solo = NativeEngine::default();
            let (mut s0, mut q0) = (Vec::new(), Vec::new());
            solo.pull_batch(&ds, &reqs, metric, &mut s0, &mut q0);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            remote.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
            assert_eq!(s0, s1, "big wave sums ring={shards} {metric:?}");
            assert_eq!(q0, q1, "big wave sqs ring={shards} {metric:?}");
        }
    }
}

#[test]
fn pull_batch_case_matrix_through_in_flight_tickets_bitwise() {
    // the same case matrix as pull_batch_bitwise_over_loopback_rings,
    // but driven through the pipelined submit/complete API with every
    // metric's wave submitted before any is completed — in-flight
    // multiplexed waves must scatter exactly like blocking ones
    for &n in SIZES {
        let d = 64;
        let ds = synthetic::gaussian_iid(n, d, 5000 + n as u64);
        let mut rng = Rng::new(177 + n as u64);
        let n_reqs = 4;
        let queries: Vec<Vec<f32>> = (0..n_reqs)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let rowsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|i| {
                let m = if i == 2 { 0 } else { 1 + rng.below(2 * n) };
                (0..m).map(|_| rng.below(n) as u32).collect()
            })
            .collect();
        let coordsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let t = 1 + rng.below(40);
                (0..t).map(|_| rng.below(d) as u32).collect()
            })
            .collect();
        for shards in 1..=3usize {
            let (_servers, mut remote) = ring(&ds, shards);
            let reqs: Vec<PullRequest> = (0..n_reqs)
                .map(|i| PullRequest {
                    query: &queries[i],
                    rows: &rowsets[i],
                    coord_ids: &coordsets[i],
                })
                .collect();
            // submit one wave per metric, hold both in flight, then
            // complete in reverse submission order
            let t_l2 = remote.submit_pull_batch(&ds, &reqs, Metric::L2Sq);
            let t_l1 = remote.submit_pull_batch(&ds, &reqs, Metric::L1);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            remote.complete_sums(t_l1, &mut s1, &mut q1);
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            remote.complete_sums(t_l2, &mut s2, &mut q2);
            let mut solo = NativeEngine::default();
            let (mut w1, mut wq1) = (Vec::new(), Vec::new());
            solo.pull_batch(&ds, &reqs, Metric::L1, &mut w1, &mut wq1);
            let (mut w2, mut wq2) = (Vec::new(), Vec::new());
            solo.pull_batch(&ds, &reqs, Metric::L2Sq, &mut w2, &mut wq2);
            assert_eq!(s1, w1, "ticket sums n={n} ring={shards} l1");
            assert_eq!(q1, wq1, "ticket sqs n={n} ring={shards} l1");
            assert_eq!(s2, w2, "ticket sums n={n} ring={shards} l2");
            assert_eq!(q2, wq2, "ticket sqs n={n} ring={shards} l2");
        }
    }
}

#[test]
fn rings_larger_than_the_dataset_bitwise() {
    // n = 4 dataset rows served by up to 8 shard servers: most servers
    // own zero rows (and never see traffic), and row-repeats pile every
    // job onto the few owners
    let n = 4;
    let d = 96;
    let ds = synthetic::gaussian_iid(n, d, 13);
    let mut rng = Rng::new(14);
    let query: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..4096).map(|i| (i % n) as u32).collect();
    let coords: Vec<u32> = (0..64).map(|_| rng.below(d) as u32).collect();
    for shards in [2usize, 6, 8] {
        let (_servers, mut remote) = ring(&ds, shards);
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut solo = NativeEngine::default();
            let (mut s0, mut q0) = (Vec::new(), Vec::new());
            solo.partial_sums(&ds, &query, &rows, &coords, metric,
                              &mut s0, &mut q0);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            remote.partial_sums(&ds, &query, &rows, &coords, metric,
                                &mut s1, &mut q1);
            assert_eq!(s0, s1, "n<S sums ring={shards} {metric:?}");
            assert_eq!(q0, q1, "n<S sqs ring={shards} {metric:?}");
        }
    }
}

#[test]
fn empty_waves_produce_empty_outputs_without_traffic() {
    let ds = synthetic::gaussian_iid(6, 16, 17);
    let q = ds.row_vec(0);
    let (_servers, mut remote) = ring(&ds, 2);
    let (mut s, mut sq) = (Vec::new(), Vec::new());
    remote.partial_sums(&ds, &q, &[], &[1], Metric::L1, &mut s, &mut sq);
    assert!(s.is_empty() && sq.is_empty());
    let mut e = Vec::new();
    remote.exact_dists(&ds, &q, &[], Metric::L2Sq, &mut e);
    assert!(e.is_empty());
    // a pull_batch wave whose every request has an empty row list
    let reqs = [
        PullRequest { query: &q, rows: &[], coord_ids: &[0, 1] },
        PullRequest { query: &q, rows: &[], coord_ids: &[] },
    ];
    remote.pull_batch(&ds, &reqs, Metric::L2Sq, &mut s, &mut sq);
    assert!(s.is_empty() && sq.is_empty());
}

#[test]
fn replicated_rings_with_all_replicas_alive_are_bitwise() {
    // full replication, nothing dead: the failover machinery must be
    // invisible — connect prefers the first replica of each shard and
    // answers stay bitwise-identical to solo execution
    let ds = synthetic::gaussian_iid(33, 48, 21);
    let (_primaries, p_eps) = spawn_loopback_ring(&ds, 3).unwrap();
    let (_replicas, r_eps) = spawn_loopback_ring(&ds, 3).unwrap();
    let specs: Vec<String> = p_eps
        .iter()
        .zip(&r_eps)
        .map(|(p, r)| format!("{p}|{r}"))
        .collect();
    let mut remote = RemoteEngine::connect(&specs).unwrap();
    assert_eq!(remote.n_shards(), 3);
    let mut rng = Rng::new(22);
    let query: Vec<f32> = (0..48).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..99).map(|_| rng.below(33) as u32).collect();
    let coords: Vec<u32> =
        (0..13).map(|_| rng.below(48) as u32).collect();
    let mut solo = NativeEngine::default();
    for metric in [Metric::L2Sq, Metric::L1] {
        let (mut s0, mut q0) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &query, &rows, &coords, metric, &mut s0,
                          &mut q0);
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        remote.partial_sums(&ds, &query, &rows, &coords, metric, &mut s1,
                            &mut q1);
        assert_eq!(s0, s1, "replicated ring sums {metric:?}");
        assert_eq!(q0, q1, "replicated ring sqs {metric:?}");
    }
}

#[test]
fn batched_knn_driver_is_bitwise_identical_over_the_wire() {
    // end-to-end: the multi-query driver over a remote ring must produce
    // byte-identical answers, distances and unit accounting — the rng
    // stream is outside the engine, so this holds exactly
    let ds = synthetic::image_like(150, 192, 55);
    let points: Vec<usize> = (0..12).map(|i| i * 11 % 150).collect();
    let params = BanditParams { k: 3, ..Default::default() };
    let mut solo_engine = NativeEngine::default();
    let mut rng0 = Rng::new(56);
    let mut c0 = Counter::new();
    let base = knn_batch_points_dense(&ds, &points, Metric::L2Sq, &params,
                                      &mut solo_engine, &mut rng0,
                                      &mut c0);
    for shards in [2usize, 3] {
        let (_servers, mut engine) = ring(&ds, shards);
        let mut rng = Rng::new(56);
        let mut c = Counter::new();
        let got = knn_batch_points_dense(&ds, &points, Metric::L2Sq,
                                         &params, &mut engine, &mut rng,
                                         &mut c);
        assert_eq!(c0.get(), c.get(), "units diverged at ring={shards}");
        for (b, g) in base.iter().zip(&got) {
            assert_eq!(b.ids, g.ids, "ids diverged at ring={shards}");
            assert_eq!(b.dists, g.dists,
                       "dists diverged at ring={shards}");
            assert_eq!(b.metrics.dist_computations,
                       g.metrics.dist_computations);
        }
    }
}
