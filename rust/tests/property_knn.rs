//! Property tests for the k-NN drivers (using the in-repo
//! `util::proptest` harness):
//!
//! 1. Under exact pulls — a sigma bound so conservative that no
//!    estimate-based confidence interval can ever separate arms — BMO-UCB
//!    must exact-evaluate every contender, so its top-k equals brute
//!    force deterministically, for random dense and sparse instances.
//! 2. The batched multi-query driver is bitwise-identical (ids, dists,
//!    unit counts) to the per-query path under the documented rng
//!    contract (query `i` of a batch ≡ solo run under `rng.fork(i)`),
//!    for batch size 1 and larger batches alike — on the dense path
//!    *and* on the sparse path (`knn_batch_sparse` vs
//!    `knn_point_sparse`).
//! 3. Host-engine validation: `--remote` serves dense datasets only,
//!    so building a remote engine for sparse queries is a validated
//!    error, never a wire surprise.

use bmonn::baselines::exact;
use bmonn::config::EngineKind;
use bmonn::coordinator::bandit::{BanditParams, PullPolicy, SigmaMode};
use bmonn::coordinator::knn::{knn_batch_dense, knn_batch_sparse,
                              knn_point_dense, knn_point_sparse,
                              knn_query_dense, KnnResult};
use bmonn::coordinator::arms::ScalarEngine;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::prop_assert_eq;
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::build_host_engine;
use bmonn::util::proptest;
use bmonn::util::rng::Rng;

/// Parameters that force the exact-pull regime: the fixed sigma is so
/// large that every estimate-based interval stays wider than any gap, so
/// an arm can only be emitted once it (and its runner-up) have collapsed
/// to exact means via the MAX_PULLS cap.
fn exact_pull_params(k: usize) -> BanditParams {
    BanditParams {
        k,
        delta: 0.01,
        sigma: SigmaMode::Fixed(1e6),
        epsilon: 0.0,
        policy: PullPolicy { init_pulls: 4, round_arms: 8, round_pulls: 16 },
    }
}

#[test]
fn exact_pull_regime_equals_bruteforce_dense() {
    proptest::check(10, |rng| {
        let n = 8 + rng.below(32);
        let d = 16 + rng.below(80);
        let k = 1 + rng.below(3);
        let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
        let truth = exact::knn_point(&ds, 0, k, Metric::L2Sq,
                                     &mut Counter::new());
        let mut engine = ScalarEngine;
        let mut qrng = rng.fork(1);
        let mut c = Counter::new();
        let res = knn_point_dense(&ds, 0, Metric::L2Sq,
                                  &exact_pull_params(k), &mut engine,
                                  &mut qrng, &mut c);
        // emission order is increasing θ, so this matches the sorted
        // brute-force ids exactly (continuous data: no ties)
        prop_assert_eq!(res.ids, truth.ids, "dense n={n} d={d} k={k}");
        Ok(())
    });
}

#[test]
fn exact_pull_regime_equals_bruteforce_sparse() {
    proptest::check(8, |rng| {
        let n = 8 + rng.below(24);
        let d = 60 + rng.below(100);
        let ds = synthetic::rna_like(n, d, 0.2, rng.next_u64());
        let truth = exact::knn_point_sparse(&ds, 0, 2, Metric::L1,
                                            &mut Counter::new());
        let mut params = exact_pull_params(2);
        // sparse MAX_PULLS is |S_q|+|S_i|, often below the dense init —
        // keep init within every arm's cap
        params.policy.init_pulls = 1;
        let mut qrng = rng.fork(1);
        let mut c = Counter::new();
        let res = knn_point_sparse(&ds, 0, Metric::L1, &params, &mut qrng,
                                   &mut c);
        prop_assert_eq!(res.ids, truth.ids, "sparse n={n} d={d}");
        Ok(())
    });
}

#[test]
fn sparse_batch_bitwise_identical_to_per_query() {
    proptest::check(8, |rng| {
        let n = 8 + rng.below(24);
        let d = 60 + rng.below(100);
        let ds = synthetic::rna_like(n, d, 0.2, rng.next_u64());
        let mut params = exact_pull_params(2);
        // sparse MAX_PULLS is |S_q|+|S_i|, often below the dense init —
        // keep init within every arm's cap
        params.policy.init_pulls = 1;
        let points: Vec<usize> = (0..4).map(|i| (i * 3) % n).collect();
        let seed = rng.next_u64();
        // per-query: query i under rng.fork(i) — the documented contract
        let mut base = Rng::new(seed);
        let mut solo_units = 0u64;
        let solo: Vec<KnnResult> = points
            .iter()
            .enumerate()
            .map(|(i, &q)| {
                let mut qrng = base.fork(i as u64);
                let mut c = Counter::new();
                let out = knn_point_sparse(&ds, q, Metric::L1, &params,
                                           &mut qrng, &mut c);
                solo_units += c.get();
                out
            })
            .collect();
        let mut base = Rng::new(seed);
        let mut c = Counter::new();
        let batch = knn_batch_sparse(&ds, &points, Metric::L1, &params,
                                     &mut base, &mut c);
        prop_assert_eq!(batch.len(), solo.len(), "sparse n={n} d={d}");
        for (i, (s, b)) in solo.iter().zip(&batch).enumerate() {
            prop_assert_eq!(&s.ids, &b.ids,
                            "sparse batch ids diverged (n={n} d={d} \
                             query {i})");
            // f64 equality on purpose: the lockstep driver must be
            // bit-identical to the per-query run, not merely close
            prop_assert_eq!(&s.dists, &b.dists,
                            "sparse batch dists diverged (n={n} d={d} \
                             query {i})");
            prop_assert_eq!(s.metrics.dist_computations,
                            b.metrics.dist_computations,
                            "sparse unit accounting diverged (n={n} \
                             d={d} query {i})");
        }
        prop_assert_eq!(solo_units, c.get(),
                        "sparse shared counter diverged (n={n} d={d})");
        Ok(())
    });
}

#[test]
fn build_host_engine_rejects_remote_sparse_queries() {
    // the check is pure validation: the endpoint is never dialed, so an
    // unroutable spec is fine here
    let remote = vec!["127.0.0.1:1".to_string()];
    let err = build_host_engine(EngineKind::Native, 1, &remote, false,
                                KernelChoice::Auto, false, true, None)
        .map(|_| ())
        .expect_err("--remote with sparse data must be refused");
    assert!(err.contains("dense"),
            "the refusal must explain the dense-only wire: {err}");
    // the same sparse data with no ring builds a local engine normally
    build_host_engine(EngineKind::Native, 1, &[], false,
                      KernelChoice::Auto, false, true, None)
        .map(|_| ())
        .expect("sparse queries without --remote use the local engine");
}

/// Solo answers under the batch driver's rng contract.
fn solo_answers(ds: &bmonn::data::DenseDataset, queries: &[Vec<f32>],
                params: &BanditParams, seed: u64)
                -> (Vec<KnnResult>, u64) {
    let mut base = Rng::new(seed);
    let mut engine = NativeEngine::default();
    let rngs: Vec<Rng> =
        (0..queries.len()).map(|i| base.fork(i as u64)).collect();
    let mut total = 0u64;
    let res = queries
        .iter()
        .zip(rngs)
        .map(|(q, mut r)| {
            let mut c = Counter::new();
            let out = knn_query_dense(ds, q, Metric::L2Sq, params,
                                      &mut engine, &mut r, &mut c);
            total += c.get();
            out
        })
        .collect();
    (res, total)
}

#[test]
fn batch_matches_per_query_on_1k_by_256() {
    // acceptance-criteria scale: fixed seed, 1000×256 synthetic dataset —
    // the batch driver must return the same neighbor ids as per-query
    // knn_query_dense (it is in fact bitwise-identical, which is stronger
    // than set equality)
    let ds = synthetic::image_like(1000, 256, 77);
    let queries: Vec<Vec<f32>> =
        (0..16).map(|i| ds.row_vec((i * 61) % 1000)).collect();
    let params = BanditParams { k: 5, ..Default::default() };
    let (solo, _) = solo_answers(&ds, &queries, &params, 78);
    let mut base = Rng::new(78);
    let mut engine = NativeEngine::default();
    let mut c = Counter::new();
    let batch = knn_batch_dense(&ds, &queries, Metric::L2Sq, &params,
                                &mut engine, &mut base, &mut c);
    for (i, (s, b)) in solo.iter().zip(&batch).enumerate() {
        assert_eq!(s.ids, b.ids, "query {i}");
    }
}

#[test]
fn batch_driver_bitwise_identical_to_per_query() {
    for &(nq, seed) in &[(1usize, 51u64), (4, 52), (9, 53)] {
        let ds = synthetic::image_like(80, 128, seed);
        let queries: Vec<Vec<f32>> =
            (0..nq).map(|i| ds.row_vec((i * 7) % 80)).collect();
        let params = BanditParams { k: 3, ..Default::default() };
        let (solo, solo_units) = solo_answers(&ds, &queries, &params, seed);
        let mut base = Rng::new(seed);
        let mut engine = NativeEngine::default();
        let mut c = Counter::new();
        let batch = knn_batch_dense(&ds, &queries, Metric::L2Sq, &params,
                                    &mut engine, &mut base, &mut c);
        assert_eq!(batch.len(), nq);
        for (i, (s, b)) in solo.iter().zip(&batch).enumerate() {
            assert_eq!(s.ids, b.ids, "ids diverged (nq={nq}, query {i})");
            // f64 equality on purpose: the coalesced engine pass must be
            // bit-identical, not merely close
            assert_eq!(s.dists, b.dists,
                       "dists diverged (nq={nq}, query {i})");
            assert_eq!(s.metrics.dist_computations,
                       b.metrics.dist_computations,
                       "unit accounting diverged (nq={nq}, query {i})");
        }
        assert_eq!(solo_units, c.get(),
                   "shared counter diverged (nq={nq})");
    }
}
