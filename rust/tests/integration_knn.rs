//! Integration tests: the full BMO-NN stack (coordinator + engines + data)
//! against brute force, across metrics, policies, and Monte Carlo boxes.

use bmonn::baselines::exact;
use bmonn::coordinator::arms::ScalarEngine;
use bmonn::coordinator::bandit::{BanditParams, PullPolicy, SigmaMode};
use bmonn::coordinator::knn::{knn_graph_sparse, knn_point_dense,
                              knn_point_sparse, knn_query_dense};
use bmonn::coordinator::pac;
use bmonn::data::rotate::Rotation;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::util::rng::Rng;

fn params(k: usize) -> BanditParams {
    BanditParams { k, delta: 0.01, ..Default::default() }
}

fn set_eq(a: &[u32], b: &[u32]) -> bool {
    let x: std::collections::HashSet<_> = a.iter().collect();
    let y: std::collections::HashSet<_> = b.iter().collect();
    x == y
}

#[test]
fn dense_l2_many_queries_high_accuracy() {
    let data = synthetic::image_like(400, 1024, 1);
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(2);
    let mut c = Counter::new();
    let mut correct = 0;
    let trials = 30;
    for q in 0..trials {
        let truth = exact::knn_point(&data, q, 5, Metric::L2Sq,
                                     &mut Counter::new());
        let mut qrng = rng.fork(q as u64);
        let got = knn_point_dense(&data, q, Metric::L2Sq, &params(5),
                                  &mut engine, &mut qrng, &mut c);
        correct += set_eq(&got.ids, &truth.ids) as usize;
    }
    assert!(correct >= trials - 1, "accuracy {correct}/{trials}");
    // and it must be far cheaper than brute force
    let brute = (trials * 399 * 1024) as u64;
    assert!(c.get() < brute / 2, "units {} vs brute {brute}", c.get());
}

#[test]
fn dense_l1_matches_bruteforce() {
    let data = synthetic::image_like(200, 512, 3);
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(4);
    let mut c = Counter::new();
    let mut correct = 0;
    for q in 0..15 {
        let truth = exact::knn_point(&data, q, 3, Metric::L1,
                                     &mut Counter::new());
        let mut qrng = rng.fork(q as u64);
        let got = knn_point_dense(&data, q, Metric::L1, &params(3),
                                  &mut engine, &mut qrng, &mut c);
        correct += set_eq(&got.ids, &truth.ids) as usize;
    }
    assert!(correct >= 14, "accuracy {correct}/15");
}

#[test]
fn faithful_algorithm1_policy_exact() {
    let data = synthetic::gaussian_means(60, 512, 4.0, 1.0, 5);
    let mut engine = ScalarEngine;
    let mut rng = Rng::new(6);
    let mut c = Counter::new();
    let p = BanditParams {
        k: 3,
        policy: PullPolicy::faithful(),
        ..Default::default()
    };
    let truth = exact::knn_point(&data, 0, 3, Metric::L2Sq,
                                 &mut Counter::new());
    let got = knn_point_dense(&data, 0, Metric::L2Sq, &p, &mut engine,
                              &mut rng, &mut c);
    assert!(set_eq(&got.ids, &truth.ids),
            "got {:?} want {:?}", got.ids, truth.ids);
}

#[test]
fn rotated_box_reduces_pulls_on_spiky_data() {
    // Lemma 3's setting: points that differ in few coordinates -> heavy
    // per-coordinate tails -> rotation should reduce sample complexity.
    let (n, d) = (150, 1024);
    let mut data = bmonn::data::DenseDataset::zeros(n, d);
    let mut rng = Rng::new(7);
    for i in 1..n {
        // each point differs from origin in 8 random spiky coords
        for _ in 0..8 {
            let j = rng.below(d);
            data.row_mut(i)[j] = 2.0 + rng.f32() * (i as f32 / n as f32);
        }
    }
    let truth = exact::knn_point(&data, 0, 1, Metric::L2Sq,
                                 &mut Counter::new());
    // unrotated
    let mut engine = NativeEngine::default();
    let mut c_plain = Counter::new();
    let mut r1 = Rng::new(8);
    let got_plain = knn_point_dense(&data, 0, Metric::L2Sq, &params(1),
                                    &mut engine, &mut r1, &mut c_plain);
    // rotated (distances preserved, so ground truth ids carry over)
    let mut r2 = Rng::new(9);
    let (rotated, _rot) = Rotation::rotate_dataset(&data, &mut r2);
    let mut c_rot = Counter::new();
    let mut r3 = Rng::new(8);
    let got_rot = knn_point_dense(&rotated, 0, Metric::L2Sq, &params(1),
                                  &mut engine, &mut r3, &mut c_rot);
    assert!(set_eq(&got_rot.ids, &truth.ids), "rotated answer wrong");
    assert!(set_eq(&got_plain.ids, &truth.ids), "plain answer wrong");
    // the rotation should not make things significantly worse; on spiky
    // data it typically helps (paper Fig 7) — allow generous slack for CI
    assert!(
        c_rot.get() as f64 <= 1.5 * c_plain.get() as f64,
        "rotation exploded cost: {} vs {}", c_rot.get(), c_plain.get()
    );
}

#[test]
fn sparse_l1_graph_matches_bruteforce() {
    let data = synthetic::rna_like(80, 600, 0.08, 10);
    let mut rng = Rng::new(11);
    let mut c = Counter::new();
    let g = knn_graph_sparse(&data, Metric::L1, &params(3), &mut rng,
                             &mut c);
    let mut correct = 0;
    for q in 0..data.n {
        let truth = exact::knn_point_sparse(&data, q, 3, Metric::L1,
                                            &mut Counter::new());
        correct += set_eq(&g.neighbors[q], &truth.ids) as usize;
    }
    assert!(correct >= data.n - 2, "graph accuracy {correct}/{}", data.n);
}

#[test]
fn external_query_roundtrip() {
    let data = synthetic::image_like(150, 256, 12);
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(13);
    let mut c = Counter::new();
    // query = noisy copy of row 42
    let mut q = data.row_vec(42);
    for v in q.iter_mut() {
        v.clone_from(&(*v + 0.0005));
    }
    let res = knn_query_dense(&data, &q, Metric::L2Sq, &params(1),
                              &mut engine, &mut rng, &mut c);
    assert_eq!(res.ids[0], 42);
}

#[test]
fn pac_mode_eps_correct_and_cheaper() {
    let data = synthetic::power_law_gaps(300, 2048, 0.4, 1.0, 14);
    let mut engine = NativeEngine::default();
    // exact run
    let mut c_exact = Counter::new();
    let mut r1 = Rng::new(15);
    let _ = knn_point_dense(&data, 0, Metric::L2Sq, &params(1),
                            &mut engine, &mut r1, &mut c_exact);
    // PAC run
    let eps = 0.4;
    let mut p = params(1);
    p.epsilon = eps;
    let mut c_pac = Counter::new();
    let mut r2 = Rng::new(15);
    let res = knn_point_dense(&data, 0, Metric::L2Sq, &p, &mut engine,
                              &mut r2, &mut c_pac);
    assert!(pac::is_eps_correct(&data, 0, Metric::L2Sq, &res, 1, eps));
    assert!(c_pac.get() <= c_exact.get());
}

#[test]
fn cost_capped_at_2nd_even_on_adversarial_ties() {
    // all points equidistant: maximum difficulty, algorithm must fall
    // back to exact evaluation everywhere and still terminate within 2nd
    let (n, d) = (40, 128);
    let mut data = bmonn::data::DenseDataset::zeros(n, d);
    for i in 1..n {
        // all at exactly the same distance: one-hot at different coords
        data.row_mut(i)[i % d] = 1.0;
    }
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(16);
    let mut c = Counter::new();
    let res = knn_point_dense(&data, 0, Metric::L2Sq, &params(5),
                              &mut engine, &mut rng, &mut c);
    assert_eq!(res.ids.len(), 5);
    let cap = 2 * (n as u64) * (d as u64) + (n as u64) * 32; // + init slack
    assert!(c.get() <= cap, "units {} exceed 2nd cap {cap}", c.get());
}

#[test]
fn deterministic_given_seed() {
    let data = synthetic::image_like(120, 512, 17);
    let run = |seed: u64| -> (Vec<u32>, u64) {
        let mut engine = NativeEngine::default();
        let mut rng = Rng::new(seed);
        let mut c = Counter::new();
        let r = knn_point_dense(&data, 3, Metric::L2Sq, &params(4),
                                &mut engine, &mut rng, &mut c);
        (r.ids, c.get())
    };
    let (ids1, u1) = run(99);
    let (ids2, u2) = run(99);
    assert_eq!(ids1, ids2);
    assert_eq!(u1, u2);
}

#[test]
fn fixed_sigma_theorem_regime() {
    // With a valid known sigma bound (Theorem 1's setting), error over
    // many trials stays within delta.
    let trials = 25;
    let mut errors = 0;
    for t in 0..trials {
        let data = synthetic::gaussian_means(80, 256, 4.0, 1.0, 100 + t);
        let truth = exact::knn_point(&data, 0, 1, Metric::L2Sq,
                                     &mut Counter::new());
        let mut engine = NativeEngine::default();
        let mut rng = Rng::new(200 + t);
        let mut c = Counter::new();
        let p = BanditParams {
            k: 1,
            delta: 0.05,
            sigma: SigmaMode::Fixed(12.0),
            ..Default::default()
        };
        let got = knn_point_dense(&data, 0, Metric::L2Sq, &p, &mut engine,
                                  &mut rng, &mut c);
        errors += (got.ids != truth.ids) as usize;
    }
    assert!(errors <= 2, "errors {errors}/{trials} exceeds delta regime");
}
