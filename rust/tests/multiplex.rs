//! The pipelined wave scheduler under adversarial delivery: tagged
//! replies shuffled and interleaved across concurrent in-flight waves,
//! completion out of submission order, several engines multiplexed over
//! one shared `RingClient`, and mid-wave endpoint death while submitted
//! tickets are in flight — with every answer pinned **bitwise** against
//! a solo `NativeEngine`.
//!
//! Real shard servers cannot be told in which order to reply, so the
//! shuffle tests speak the v2 wire protocol through a scripted
//! in-process server that computes real answers (with the same
//! `NativeEngine` kernel) but releases the replies in a seeded random
//! order. The demux reader must route every reply by its wave tag, no
//! matter the order.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use bmonn::coordinator::arms::PullEngine;
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::knn_batch_points_dense;
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::placement::{PlacementMap, RetryPolicy};
use bmonn::runtime::remote::{spawn_loopback_ring, RemoteEngine,
                             RemoteOptions, RingClient};
use bmonn::runtime::wire::{self, Message};
use bmonn::util::rng::Rng;

/// A scripted v2 shard server for one connection: handshakes honestly
/// for the whole dataset (1 shard), then reads `n_waves` compute
/// requests, computes real answers with `NativeEngine`, and writes the
/// replies in the order given by `reply_order` (indices into arrival
/// order). Returns the join handle; the thread exits after replying.
fn scripted_server(data: DenseDataset, n_waves: usize,
                   reply_order: Vec<usize>)
                   -> (String, std::thread::JoinHandle<()>) {
    assert_eq!(reply_order.len(), n_waves);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let ep = listener.local_addr().unwrap().to_string();
    let hash = wire::dataset_fingerprint(data.n, 0, &data);
    let handle = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        s.set_nodelay(true).unwrap();
        let mut buf = Vec::new();
        // handshake
        wire::read_frame(&mut s, &mut buf).unwrap();
        let hello = Message::decode(&buf).unwrap();
        let Message::Hello { wave_id, version } = hello else {
            panic!("expected hello, got {}", hello.kind());
        };
        assert_eq!(version, wire::PROTOCOL_VERSION);
        let mut out = Vec::new();
        wire::encode_hello_ack(&mut out, wave_id, wire::PROTOCOL_VERSION,
                               data.n as u64, data.d as u64, 0,
                               data.n as u64, hash);
        wire::write_frame(&mut s, &out).unwrap();
        // read every request first (nothing replied yet): all the
        // client's waves are genuinely in flight simultaneously
        let mut engine = NativeEngine::default();
        let mut replies: Vec<Vec<u8>> = Vec::new();
        for _ in 0..n_waves {
            wire::read_frame(&mut s, &mut buf).unwrap();
            let msg = Message::decode(&buf).unwrap();
            let wid = msg.wave_id();
            let mut out = Vec::new();
            match msg {
                Message::PartialSums { metric, query, rows, coord_ids,
                                       .. } => {
                    let (mut sum, mut sq) = (Vec::new(), Vec::new());
                    engine.partial_sums(&data, &query, &rows, &coord_ids,
                                        metric, &mut sum, &mut sq);
                    wire::encode_sums(&mut out, wid, &sum, &sq);
                }
                Message::ExactDists { metric, query, rows, .. } => {
                    let mut vals = Vec::new();
                    engine.exact_dists(&data, &query, &rows, metric,
                                       &mut vals);
                    wire::encode_dists(&mut out, wid, &vals);
                }
                other => panic!("unexpected {}", other.kind()),
            }
            replies.push(out);
        }
        // release the replies in the scripted (shuffled) order
        for &i in &reply_order {
            wire::write_frame(&mut s, &replies[i]).unwrap();
        }
        // hold the connection open until the client is done reading
        let _ = wire::read_frame(&mut s, &mut buf);
    });
    (ep, handle)
}

#[test]
fn shuffled_reply_delivery_is_routed_by_tag_bitwise() {
    // property: for arbitrary concurrent waves and an arbitrary reply
    // permutation, every completed wave is bitwise identical to solo
    // NativeEngine — delivery order must be invisible
    let mut rng = Rng::new(4242);
    for case in 0..12u64 {
        let n = 6 + rng.below(20);
        let d = 4 + rng.below(24);
        let ds = synthetic::gaussian_iid(n, d, 900 + case);
        let n_waves = 2 + rng.below(5);
        let mut order: Vec<usize> = (0..n_waves).collect();
        rng.shuffle(&mut order);
        let (ep, server) = scripted_server(ds.clone(), n_waves,
                                           order.clone());
        let mut eng = RemoteEngine::connect_with_timeout(
            &[ep], Some(Duration::from_secs(10))).unwrap();
        // stage arbitrary waves (mixed kinds), submit them all, then
        // complete them in a second, independent shuffled order
        let mut solo = NativeEngine::default();
        let mut tickets = Vec::new();
        let mut kinds = Vec::new(); // true = sums wave
        let mut want: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
        for _ in 0..n_waves {
            let metric = if rng.bool(0.5) { Metric::L2Sq } else {
                Metric::L1 };
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32).collect();
            let rows: Vec<u32> =
                (0..1 + rng.below(2 * n)).map(|_| rng.below(n) as u32)
                    .collect();
            if rng.bool(0.5) {
                let coords: Vec<u32> =
                    (0..1 + rng.below(16)).map(|_| rng.below(d) as u32)
                        .collect();
                let (mut s, mut q) = (Vec::new(), Vec::new());
                solo.partial_sums(&ds, &query, &rows, &coords, metric,
                                  &mut s, &mut q);
                want.push((s, q));
                tickets.push(eng.submit_partial_sums(&ds, &query, &rows,
                                                     &coords, metric));
                kinds.push(true);
            } else {
                let mut v = Vec::new();
                solo.exact_dists(&ds, &query, &rows, metric, &mut v);
                want.push((v, Vec::new()));
                tickets.push(eng.submit_exact_dists(&ds, &query, &rows,
                                                    metric));
                kinds.push(false);
            }
        }
        let mut complete_order: Vec<usize> = (0..n_waves).collect();
        rng.shuffle(&mut complete_order);
        let mut got: Vec<Option<(Vec<f64>, Vec<f64>)>> =
            (0..n_waves).map(|_| None).collect();
        // consume tickets in the shuffled completion order
        let mut tickets: Vec<Option<_>> =
            tickets.into_iter().map(Some).collect();
        for &i in &complete_order {
            let t = tickets[i].take().unwrap();
            if kinds[i] {
                let (mut s, mut q) = (Vec::new(), Vec::new());
                eng.complete_sums(t, &mut s, &mut q);
                got[i] = Some((s, q));
            } else {
                let mut v = Vec::new();
                eng.complete_dists(t, &mut v);
                got[i] = Some((v, Vec::new()));
            }
        }
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            let g = g.as_ref().unwrap();
            assert_eq!(w, g,
                       "wave {i} diverged (case {case}, reply order \
                        {order:?}, completion order {complete_order:?})");
        }
        // the scripted server withheld every reply until all waves were
        // submitted, so all of them were pending on the one connection
        // simultaneously — deterministically, not by timing luck
        assert!(eng.client().max_inflight_per_conn() >= n_waves as u64,
                "all {n_waves} waves must have been in flight at once \
                 (high-water {})",
                eng.client().max_inflight_per_conn());
        drop(eng); // closes the connection; the server thread exits
        server.join().unwrap();
    }
}

#[test]
fn concurrent_batch_drivers_share_one_client_bitwise() {
    // the query server's sharing pattern: several engines over one
    // RingClient on separate threads, each running a full batched k-NN
    // workload — all answers bitwise identical to solo execution, and
    // the client must witness >= 2 waves in flight on one connection
    let ds = synthetic::image_like(120, 96, 71);
    let points: Vec<usize> = (0..16).map(|i| (i * 7) % 120).collect();
    let params = BanditParams { k: 3, ..Default::default() };
    let mut solo_engine = NativeEngine::default();
    let mut rng0 = Rng::new(72);
    let mut c0 = Counter::new();
    let base = knn_batch_points_dense(&ds, &points, Metric::L2Sq, &params,
                                      &mut solo_engine, &mut rng0,
                                      &mut c0);
    let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let client = Arc::new(RingClient::connect(&eps).unwrap());
    let results: Vec<_> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let client = client.clone();
                let (ds, points, params) = (&ds, &points, &params);
                sc.spawn(move || {
                    let mut engine =
                        RemoteEngine::from_client(client);
                    let mut rng = Rng::new(72);
                    let mut c = Counter::new();
                    let res = knn_batch_points_dense(
                        ds, points, Metric::L2Sq, params, &mut engine,
                        &mut rng, &mut c);
                    (res, c.get())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (res, units) in &results {
        assert_eq!(*units, c0.get(), "unit accounting diverged");
        for (b, g) in base.iter().zip(res) {
            assert_eq!(b.ids, g.ids);
            assert_eq!(b.dists, g.dists);
            assert_eq!(b.metrics.dist_computations,
                       g.metrics.dist_computations);
        }
    }
    assert!(client.max_inflight_per_conn() >= 2,
            "3 concurrent drivers over one client never overlapped \
             waves on a connection (high-water {})",
            client.max_inflight_per_conn());
}

#[test]
fn endpoint_death_with_submitted_tickets_fails_over_bitwise() {
    // submit several waves so they are in flight on the primary's
    // connections, kill the primary, then complete: every sub-wave that
    // was in flight on the dead endpoint must re-issue itself to the
    // replica and the completed results must stay bitwise identical
    let ds = synthetic::gaussian_iid(48, 24, 81);
    let (mut primaries, p_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let (_replicas, r_eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let specs: Vec<String> = p_eps
        .iter()
        .zip(&r_eps)
        .map(|(p, r)| format!("{p}|{r}"))
        .collect();
    let opts = RemoteOptions {
        timeout: Some(Duration::from_secs(10)),
        degraded: false,
        retry: RetryPolicy {
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_millis(200),
        },
        ..RemoteOptions::default()
    };
    let mut eng = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(), opts).unwrap();
    let q0 = ds.row_vec(0);
    let q1 = ds.row_vec(1);
    let rows: Vec<u32> = (0..48).collect();
    let coords: Vec<u32> = (0..12).collect();
    // make sure the primary connections carry traffic first
    let (mut s, mut sq) = (Vec::new(), Vec::new());
    eng.partial_sums(&ds, &q0, &rows, &coords, Metric::L2Sq, &mut s,
                     &mut sq);
    let mut solo = NativeEngine::default();
    let (mut w0, mut wq0) = (Vec::new(), Vec::new());
    solo.partial_sums(&ds, &q0, &rows, &coords, Metric::L2Sq, &mut w0,
                      &mut wq0);
    assert_eq!(s, w0);
    // two waves in flight, then the primaries die under them
    let t0 = eng.submit_partial_sums(&ds, &q0, &rows, &coords,
                                     Metric::L2Sq);
    let t1 = eng.submit_exact_dists(&ds, &q1, &rows, Metric::L1);
    for p in primaries.iter_mut() {
        p.stop();
    }
    drop(primaries);
    let (mut s0, mut sq0) = (Vec::new(), Vec::new());
    eng.complete_sums(t0, &mut s0, &mut sq0);
    let mut d1 = Vec::new();
    eng.complete_dists(t1, &mut d1);
    assert_eq!(s0, w0, "failed-over sums wave must stay bitwise");
    assert_eq!(sq0, wq0);
    let mut wd1 = Vec::new();
    solo.exact_dists(&ds, &q1, &rows, Metric::L1, &mut wd1);
    assert_eq!(d1, wd1, "failed-over dists wave must stay bitwise");
    // and the engine keeps serving on the replicas afterwards
    let (mut s2, mut sq2) = (Vec::new(), Vec::new());
    eng.partial_sums(&ds, &q0, &rows, &coords, Metric::L2Sq, &mut s2,
                     &mut sq2);
    assert_eq!(s2, w0);
}

#[test]
fn interleaved_submit_complete_from_one_caller_is_bitwise() {
    // pipelining from a single thread: keep a sliding window of waves
    // in flight over a REAL ring (2 shards), completing the oldest
    // while two more are outstanding — results identical to blocking
    let ds = synthetic::gaussian_iid(60, 32, 91);
    let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let mut eng = RemoteEngine::connect(&eps).unwrap();
    let mut solo = NativeEngine::default();
    let mut rng = Rng::new(92);
    let mut window = std::collections::VecDeque::new();
    let mut expected = std::collections::VecDeque::new();
    for step in 0..20 {
        let query: Vec<f32> =
            (0..32).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> =
            (0..1 + rng.below(120)).map(|_| rng.below(60) as u32)
                .collect();
        let coords: Vec<u32> =
            (0..1 + rng.below(8)).map(|_| rng.below(32) as u32).collect();
        let (mut ws, mut wq) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &query, &rows, &coords, Metric::L2Sq,
                          &mut ws, &mut wq);
        expected.push_back((ws, wq));
        window.push_back(eng.submit_partial_sums(&ds, &query, &rows,
                                                 &coords, Metric::L2Sq));
        if window.len() > 3 {
            let t = window.pop_front().unwrap();
            let (want_s, want_q) = expected.pop_front().unwrap();
            let (mut s, mut q) = (Vec::new(), Vec::new());
            eng.complete_sums(t, &mut s, &mut q);
            assert_eq!(s, want_s, "window wave {step} diverged");
            assert_eq!(q, want_q);
        }
    }
    while let Some(t) = window.pop_front() {
        let (want_s, want_q) = expected.pop_front().unwrap();
        let (mut s, mut q) = (Vec::new(), Vec::new());
        eng.complete_sums(t, &mut s, &mut q);
        assert_eq!(s, want_s);
        assert_eq!(q, want_q);
    }
    // (the in-flight high-water mark is asserted by the scripted-server
    // test above, where overlap is deterministic rather than a race
    // against a fast loopback server)
}
