//! HTTP front-door integration: the serving path behind `--http-port`
//! under real sockets — protocol round-trips with real status codes,
//! the result cache's byte-identity and invalidation contracts, and
//! the answers that must never be cached (deadline-exceeded, degraded
//! coverage).

use bmonn::coordinator::http::http_request;
use bmonn::coordinator::server::{Server, ServerConfig};
use bmonn::data::synthetic;
use bmonn::runtime::remote::spawn_loopback_ring;
use bmonn::util::json::Json;

use std::net::SocketAddr;

fn knn_body(q: &[f32], k: usize) -> String {
    Json::obj(vec![
        ("query", Json::f32_array(q)),
        ("k", Json::Num(k as f64)),
    ])
    .to_string()
}

fn metrics(http: &SocketAddr) -> Json {
    let (status, _, body) =
        http_request(http, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200, "/metrics: {body}");
    Json::parse(body.trim()).unwrap()
}

fn counter(m: &Json, key: &str) -> u64 {
    m.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("/metrics lost {key}: {m}")) as u64
}

#[test]
fn front_door_speaks_http_with_real_status_codes() {
    let ds = synthetic::image_like(100, 32, 7);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        http_port: Some(0),
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.expect("http_port: Some(0) must bind");
    // POST /knn: a valid query answers 200 with the knn response body
    let (status, _, body) =
        http_request(&http, "POST", "/knn",
                     Some(&knn_body(&ds.row_vec(3), 3)))
            .unwrap();
    assert_eq!(status, 200, "{body}");
    let resp = Json::parse(body.trim()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    let ids: Vec<usize> = resp
        .get("ids")
        .and_then(|a| a.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap();
    assert_eq!(ids[0], 3, "self row must be its own 1-NN");
    // GET /metrics: the stats body, with the query above counted
    let m = metrics(&http);
    assert!(counter(&m, "queries") >= 1);
    // GET /healthz answers 200
    let (status, _, _) =
        http_request(&http, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    // a malformed body is a 400, not a connection reset
    let (status, _, body) =
        http_request(&http, "POST", "/knn", Some("{not json")).unwrap();
    assert_eq!(status, 400, "{body}");
    // so is a structurally valid but invalid request (wrong dimension)
    let (status, _, body) =
        http_request(&http, "POST", "/knn",
                     Some(&knn_body(&[1.0, 2.0], 3)))
            .unwrap();
    assert_eq!(status, 400, "{body}");
    // unknown path: 404; wrong method on a known path: 405 with Allow
    let (status, _, _) =
        http_request(&http, "GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, headers, _) =
        http_request(&http, "GET", "/knn", None).unwrap();
    assert_eq!(status, 405);
    assert!(headers.iter().any(|(n, v)| n == "allow" && v == "POST"),
            "405 must name the allowed method: {headers:?}");
    // per-route latency windows: every route exercised above has its
    // own row, and unknown paths / wrong methods pool under "other"
    let m = metrics(&http);
    let routes = m.get("routes").expect("routes object in /metrics");
    for r in ["POST /knn", "GET /metrics", "GET /healthz", "other"] {
        let row = routes.get(r)
            .unwrap_or_else(|| panic!("missing route {r}: {m}"));
        assert!(row.get("count").and_then(|v| v.as_usize()).unwrap()
                    >= 1,
                "route {r} recorded nothing");
        assert!(row.get("p99_us").and_then(|v| v.as_f64()).is_some());
        assert!(row.get("mean_us").and_then(|v| v.as_f64()).is_some());
    }
    srv.stop();
}

#[test]
fn cache_hit_replays_the_fresh_bytes_and_surfaces_in_metrics() {
    let ds = synthetic::image_like(100, 32, 11);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 2,
        batch_size: 4,
        http_port: Some(0),
        cache_entries: 8,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    let body = knn_body(&ds.row_vec(9), 3);
    let (s1, _, fresh) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s1, 200, "{fresh}");
    // the hit must be byte-identical to the fresh compute
    let (s2, _, hit) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s2, 200);
    assert_eq!(hit, fresh,
               "cache hit must replay the stored bytes exactly");
    // a different query is a miss, not a collision with the entry
    let other = knn_body(&ds.row_vec(10), 3);
    let (s3, _, fresh_other) =
        http_request(&http, "POST", "/knn", Some(&other)).unwrap();
    assert_eq!(s3, 200);
    assert_ne!(fresh_other, fresh);
    let m = metrics(&http);
    assert_eq!(counter(&m, "cache_hits"), 1);
    assert_eq!(counter(&m, "cache_misses"), 2);
    assert_eq!(counter(&m, "cache_entries"), 2);
    srv.stop();
}

#[test]
fn epoch_bump_invalidates_but_the_recompute_answers_the_same_bytes() {
    let ds = synthetic::image_like(100, 32, 13);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        http_port: Some(0),
        cache_entries: 8,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    let body = knn_body(&ds.row_vec(4), 3);
    let (s1, _, fresh) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s1, 200);
    let (s2, _, _) =
        http_request(&http, "POST", "/admin/epoch-bump", Some(""))
            .unwrap();
    assert_eq!(s2, 200);
    let m = metrics(&http);
    assert_eq!(counter(&m, "epoch"), 1, "bump must advance the epoch");
    // the pre-bump entry never matches again: this is a recompute...
    let (s3, _, recomputed) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s3, 200);
    let m = metrics(&http);
    assert_eq!(counter(&m, "cache_hits"), 0,
               "the pre-bump entry must not serve post-bump queries");
    assert_eq!(counter(&m, "cache_misses"), 2);
    // ...and the seeded serving compute makes it answer the same bytes
    // as before the flip (the dataset did not actually change here)
    assert_eq!(recomputed, fresh,
               "recompute across an epoch flip diverged from the \
                original compute");
    // the post-bump entry is cached under the new epoch
    let (s4, _, hit) =
        http_request(&http, "POST", "/knn", Some(&body)).unwrap();
    assert_eq!(s4, 200);
    assert_eq!(hit, fresh);
    assert_eq!(counter(&metrics(&http), "cache_hits"), 1);
    srv.stop();
}

#[test]
fn deadline_exceeded_answers_504_and_is_never_cached() {
    let ds = synthetic::image_like(100, 32, 17);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        // the worker lingers 50ms on every non-full batch, so a 1ms
        // request budget reliably expires in-queue
        batch_wait_us: 50_000,
        http_port: Some(0),
        cache_entries: 8,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    let q = ds.row_vec(6);
    let expired = Json::obj(vec![
        ("query", Json::f32_array(&q)),
        ("k", Json::Num(3.0)),
        ("deadline_ms", Json::Num(1.0)),
    ])
    .to_string();
    let (status, _, body) =
        http_request(&http, "POST", "/knn", Some(&expired)).unwrap();
    assert_eq!(status, 504, "1ms budget against a 50ms linger: {body}");
    let resp = Json::parse(body.trim()).unwrap();
    assert_eq!(resp.get("kind").and_then(|v| v.as_str()),
               Some("deadline_exceeded"));
    // the failure was not cached: the same query under a generous
    // budget computes a real answer instead of replaying the 504
    let m = metrics(&http);
    assert_eq!(counter(&m, "cache_entries"), 0,
               "a deadline_exceeded answer must never be cached");
    let (status, _, body) =
        http_request(&http, "POST", "/knn",
                     Some(&knn_body(&q, 3)))
            .unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(counter(&metrics(&http), "cache_entries"), 1);
    srv.stop();
}

#[test]
fn degraded_coverage_answers_are_never_cached() {
    let ds = synthetic::image_like(80, 64, 23);
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints,
        degraded: true,
        http_port: Some(0),
        cache_entries: 8,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    // healthy ring: a full answer, cached
    let (s1, _, body) =
        http_request(&http, "POST", "/knn",
                     Some(&knn_body(&ds.row_vec(5), 3)))
            .unwrap();
    assert_eq!(s1, 200, "{body}");
    assert!(Json::parse(body.trim()).unwrap().get("coverage").is_none());
    assert_eq!(counter(&metrics(&http), "cache_entries"), 1);
    // kill shard 0: degraded answers still 200, coverage-annotated —
    // and they must not enter the cache
    ring[0].stop();
    let degraded_q = knn_body(&ds.row_vec(50), 3);
    let (s2, _, body) =
        http_request(&http, "POST", "/knn", Some(&degraded_q)).unwrap();
    assert_eq!(s2, 200, "degraded query must answer: {body}");
    let resp = Json::parse(body.trim()).unwrap();
    let frac = resp.get("coverage").and_then(|v| v.as_f64()).unwrap();
    assert!((frac - 0.5).abs() < 1e-9, "coverage {frac}");
    let m = metrics(&http);
    assert_eq!(counter(&m, "cache_entries"), 1,
               "a coverage-annotated answer must never be cached");
    // a repeat of the degraded query recomputes (miss), never hits
    let hits_before = counter(&m, "cache_hits");
    let (s3, _, _) =
        http_request(&http, "POST", "/knn", Some(&degraded_q)).unwrap();
    assert_eq!(s3, 200);
    assert_eq!(counter(&metrics(&http), "cache_hits"), hits_before,
               "degraded answers must be recomputed every time");
    srv.stop();
}

#[test]
fn metrics_reports_placement_epoch_and_per_endpoint_ring_health() {
    let ds = synthetic::image_like(80, 32, 31);
    let (mut ring, endpoints) = spawn_loopback_ring(&ds, 2).unwrap();
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints,
        http_port: Some(0),
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    // the current placement epoch plus one health row per endpoint,
    // live-probed: identity, connection count, epoch and fingerprint
    let m = metrics(&http);
    assert_eq!(counter(&m, "placement_epoch"), 0,
               "a ring started without --epoch serves epoch 0");
    let rows = m.get("ring").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(rows.len(), 2, "one health row per endpoint: {m}");
    for (i, ep) in rows.iter().enumerate() {
        assert_eq!(ep.get("ok"), Some(&Json::Bool(true)), "{ep}");
        assert_eq!(ep.get("shard").and_then(|v| v.as_usize()), Some(i));
        assert_eq!(ep.get("of").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(ep.get("epoch").and_then(|v| v.as_usize()), Some(0));
        assert!(ep.get("endpoint").and_then(|v| v.as_str()).is_some(),
                "health row must name its endpoint: {ep}");
        assert!(ep.get("fingerprint").and_then(|v| v.as_str()).is_some(),
                "health row must carry the dataset fingerprint: {ep}");
    }
    // a dead endpoint surfaces as ok:false with its error — the probe
    // fails fast instead of wedging /metrics
    ring[1].stop();
    let m = metrics(&http);
    let rows = m.get("ring").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(rows[0].get("ok"), Some(&Json::Bool(true)));
    assert_eq!(rows[1].get("ok"), Some(&Json::Bool(false)));
    assert!(rows[1].get("error").and_then(|v| v.as_str()).is_some(),
            "a failed probe must say why: {}", rows[1]);
    srv.stop();
}

#[test]
fn overload_sheds_with_429_and_a_retry_after_header() {
    use std::sync::atomic::{AtomicU64, Ordering};
    let ds = synthetic::image_like(100, 32, 29);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 4,
        // a long linger keeps the single queue slot reliably occupied
        batch_wait_us: 20_000,
        max_queue: 1,
        http_port: Some(0),
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let http = srv.http_addr.unwrap();
    let sheds = AtomicU64::new(0);
    let bad_header = AtomicU64::new(0);
    'burst: for _ in 0..50 {
        std::thread::scope(|sc| {
            for t in 0..8 {
                let sheds = &sheds;
                let bad_header = &bad_header;
                let ds = &ds;
                sc.spawn(move || {
                    for j in 0..4 {
                        let row = (t * 13 + j * 7) % 100;
                        let body = knn_body(&ds.row_vec(row), 3);
                        let Ok((status, headers, _)) = http_request(
                            &http, "POST", "/knn", Some(&body))
                        else {
                            continue;
                        };
                        if status == 429 {
                            sheds.fetch_add(1, Ordering::Relaxed);
                            let ok = headers.iter().any(|(n, v)| {
                                n == "retry-after"
                                    && v.parse::<u64>()
                                        .is_ok_and(|s| s >= 1)
                            });
                            if !ok {
                                bad_header
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        if sheds.load(Ordering::Relaxed) > 0 {
            break 'burst;
        }
    }
    assert!(sheds.load(Ordering::Relaxed) >= 1,
            "50 bursts against max_queue=1 never answered a 429");
    assert_eq!(bad_header.load(Ordering::Relaxed), 0,
               "every 429 must carry a whole-second Retry-After");
    srv.stop();
}
