//! Worker-pool server integration: the batched serving path under real
//! concurrent load — protocol round-trips from many simultaneous
//! connections, the malformed-input error path, and aggregate `stats`
//! consistency with per-response accounting.

use bmonn::coordinator::server::{Client, Server, ServerConfig};
use bmonn::data::synthetic;
use bmonn::util::json::Json;

fn stats(cl: &mut Client) -> Json {
    cl.request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap()
}

#[test]
fn worker_pool_under_concurrent_load() {
    let ds = synthetic::image_like(120, 96, 41);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 3,
        batch_size: 4,
        ..Default::default()
    };
    let mut srv = Server::start(ds.clone(), cfg).unwrap();
    let addr = srv.addr;
    let n_clients = 10usize;
    let per_client = 5usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|ci| {
            let qs: Vec<(usize, Vec<f32>)> = (0..per_client)
                .map(|j| {
                    let r = (ci * 7 + j * 11) % 120;
                    (r, ds.row_vec(r))
                })
                .collect();
            std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                // ping round-trip on every connection
                let pong = cl
                    .request(&Json::obj(vec![(
                        "op",
                        Json::Str("ping".into()),
                    )]))
                    .unwrap();
                assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
                let mut units = 0u64;
                for (r, q) in qs {
                    let (ids, dists, u) = cl.knn(&q, 3).unwrap();
                    assert_eq!(ids.len(), 3);
                    assert_eq!(ids[0] as usize, r,
                               "self row must be its own 1-NN");
                    assert!(u > 0, "response must carry its unit cost");
                    for w in dists.windows(2) {
                        assert!(w[0] <= w[1] + 1e-6, "dists not sorted");
                    }
                    units += u;
                }
                units
            })
        })
        .collect();
    let client_units: u64 =
        handles.into_iter().map(|h| h.join().unwrap()).sum();
    let total = (n_clients * per_client) as u64;
    assert_eq!(srv.total_queries(), total);
    // aggregate unit total must equal the sum of per-response units
    assert_eq!(srv.total_units(), client_units);
    let mut cl = Client::connect(&srv.addr).unwrap();
    let st = stats(&mut cl);
    assert_eq!(st.get("queries").unwrap().as_usize(),
               Some(total as usize));
    assert_eq!(st.get("units").unwrap().as_f64().unwrap() as u64,
               client_units);
    // batching actually happened and the accounting is self-consistent
    let batches = st.get("batches").unwrap().as_f64().unwrap();
    let mean_batch = st.get("mean_batch").unwrap().as_f64().unwrap();
    let max_batch = st.get("max_batch").unwrap().as_f64().unwrap();
    assert!(batches >= 1.0 && batches <= total as f64);
    assert!((mean_batch * batches - total as f64).abs() < 1e-6,
            "mean_batch * batches must equal queries");
    assert!((1.0..=4.0).contains(&max_batch),
            "max batch bounded by batch_size");
    assert!(st.get("batch_p99_us").and_then(|v| v.as_f64()).is_some(),
            "per-batch latency must be reported");
    srv.stop();
}

#[test]
fn client_disconnecting_while_queued_does_not_derail_the_batch() {
    // A client that vanishes between enqueue and response makes the
    // reply write fail on its I/O thread. The batch must still complete
    // for co-batched queries, the worker must survive, and `stats`
    // accounting must count the orphaned query consistently.
    use std::io::Write;

    let ds = synthetic::image_like(100, 96, 47);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1, // FIFO: the orphaned query computes with/before B's
        batch_size: 8,
        ..Default::default()
    };
    let srv = Server::start(ds.clone(), cfg).unwrap();
    let addr = srv.addr;
    // client A: enqueue one query, then vanish without reading the reply
    {
        let mut a = std::net::TcpStream::connect(addr).unwrap();
        let req = Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(&ds.row_vec(3))),
            ("k", Json::Num(2.0)),
        ]);
        a.write_all(req.to_string().as_bytes()).unwrap();
        a.write_all(b"\n").unwrap();
        a.flush().unwrap();
        // give the I/O thread time to parse + enqueue before the drop
        std::thread::sleep(std::time::Duration::from_millis(100));
    } // A's socket closes here; the pending reply write will fail
    // client B keeps the worker busy and must be unaffected
    let mut b = Client::connect(&addr).unwrap();
    for i in 0..3usize {
        let r = (11 + i * 13) % 100;
        let (ids, _, units) = b.knn(&ds.row_vec(r), 2).unwrap();
        assert_eq!(ids[0] as usize, r, "co-batched query {i} broke");
        assert!(units > 0);
    }
    // single FIFO worker: by the time B's queries are answered, A's
    // orphaned query has been computed and accounted
    let st = stats(&mut b);
    assert_eq!(st.get("queries").unwrap().as_usize(), Some(4),
               "orphaned query must still be counted");
    let batches = st.get("batches").unwrap().as_f64().unwrap();
    let mean_batch = st.get("mean_batch").unwrap().as_f64().unwrap();
    assert!((mean_batch * batches - 4.0).abs() < 1e-6,
            "batch accounting must include the orphaned query");
    assert_eq!(srv.total_queries(), 4);
}

#[test]
fn wait_a_little_batching_coalesces_light_load() {
    // with batch_wait_us set, a single worker that found a non-full
    // batch lingers for more arrivals: several near-simultaneous
    // queries from independent connections land in very few batches,
    // and the realized batch sizes are visible via stats
    let ds = synthetic::image_like(80, 64, 53);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        n_workers: 1,
        batch_size: 8,
        batch_wait_us: 500_000, // 0.5s — generous vs. connect skew
        ..Default::default()
    };
    let srv = Server::start(ds.clone(), cfg).unwrap();
    let addr = srv.addr;
    let n_clients = 4usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let q = ds.row_vec(i * 7);
            std::thread::spawn(move || {
                let mut cl = Client::connect(&addr).unwrap();
                let (ids, _, units) = cl.knn(&q, 2).unwrap();
                assert_eq!(ids[0] as usize, i * 7);
                assert!(units > 0);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut cl = Client::connect(&srv.addr).unwrap();
    let st = stats(&mut cl);
    assert_eq!(st.get("queries").unwrap().as_usize(), Some(n_clients));
    let batches = st.get("batches").unwrap().as_f64().unwrap();
    let mean_batch = st.get("mean_batch").unwrap().as_f64().unwrap();
    // the lingering worker must have coalesced the burst: 4 queries in
    // at most 2 batches (scheduling noise allowance), i.e. mean >= 2
    assert!(batches <= 2.0,
            "wait-a-little server split 4 concurrent queries into \
             {batches} batches");
    assert!(mean_batch >= 2.0, "mean batch {mean_batch}");
    // the setting itself is observable
    assert_eq!(st.get("batch_wait_us").and_then(|v| v.as_f64()),
               Some(500_000.0));
}

#[test]
fn malformed_json_and_protocol_roundtrips() {
    let ds = synthetic::image_like(40, 32, 43);
    let q = ds.row_vec(3);
    let mut srv = Server::start(
        ds,
        ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut cl = Client::connect(&srv.addr).unwrap();
    // malformed JSON gets an error response, not a dropped connection
    let bad = cl.send_raw("{\"op\": \"knn\", oops}").unwrap();
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    assert!(bad.get("error").unwrap().as_str().unwrap()
        .contains("bad json"));
    // the same connection still serves valid traffic afterwards
    let (ids, _, _) = cl.knn(&q, 1).unwrap();
    assert_eq!(ids[0], 3);
    // unknown op
    let unk = cl
        .request(&Json::obj(vec![("op", Json::Str("nope".into()))]))
        .unwrap();
    assert_eq!(unk.get("ok"), Some(&Json::Bool(false)));
    // shutdown round-trip: acked, then the server winds down cleanly
    let ack = cl
        .request(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))
        .unwrap();
    assert_eq!(ack.get("ok"), Some(&Json::Bool(true)));
    srv.stop();
    assert_eq!(srv.total_queries(), 1);
}
