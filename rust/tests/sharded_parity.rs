//! Shard determinism: `ShardedEngine<NativeEngine>` must be **bitwise**
//! identical to a single-threaded `NativeEngine` for every shard count —
//! including uneven splits, shards with zero rows, and n < S — across
//! `partial_sums`, `exact_dists` and the coalesced `pull_batch` path,
//! and end-to-end through the batched k-NN driver.

use bmonn::coordinator::arms::{PullEngine, PullRequest};
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::knn_batch_points_dense;
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::sharded::ShardedEngine;
use bmonn::util::rng::Rng;

/// Dataset sizes that produce uneven splits, zero-row shards (n < S for
/// the larger shard counts), and exact divisions.
const SIZES: &[usize] = &[3, 5, 8, 16, 33];

#[test]
fn partial_sums_and_exact_dists_bitwise_for_shard_counts_1_to_8() {
    for &n in SIZES {
        let d = 40;
        let ds = synthetic::gaussian_iid(n, d, 1000 + n as u64);
        let mut rng = Rng::new(n as u64);
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        // duplicate and out-of-order rows are legal pull targets
        let rows: Vec<u32> = (0..3 * n)
            .map(|_| rng.below(n) as u32)
            .collect();
        let coords: Vec<u32> =
            (0..17).map(|_| rng.below(d) as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut solo = NativeEngine::default();
            let (mut s0, mut q0) = (Vec::new(), Vec::new());
            solo.partial_sums(&ds, &query, &rows, &coords, metric,
                              &mut s0, &mut q0);
            let mut e0 = Vec::new();
            solo.exact_dists(&ds, &query, &rows, metric, &mut e0);
            for shards in 1..=8usize {
                let mut sharded =
                    ShardedEngine::new(NativeEngine::default(), shards);
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                sharded.partial_sums(&ds, &query, &rows, &coords, metric,
                                     &mut s1, &mut q1);
                assert_eq!(s0, s1, "sums n={n} shards={shards} {metric:?}");
                assert_eq!(q0, q1, "sqs n={n} shards={shards} {metric:?}");
                let mut e1 = Vec::new();
                sharded.exact_dists(&ds, &query, &rows, metric, &mut e1);
                assert_eq!(e0, e1,
                           "exact n={n} shards={shards} {metric:?}");
            }
        }
    }
}

#[test]
fn pull_batch_bitwise_for_shard_counts_1_to_8() {
    for &n in SIZES {
        let d = 64;
        let ds = synthetic::gaussian_iid(n, d, 2000 + n as u64);
        let mut rng = Rng::new(77 + n as u64);
        let n_reqs = 4;
        let queries: Vec<Vec<f32>> = (0..n_reqs)
            .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let rowsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|i| {
                // one empty request exercises the zero-length range path
                let m = if i == 2 { 0 } else { 1 + rng.below(2 * n) };
                (0..m).map(|_| rng.below(n) as u32).collect()
            })
            .collect();
        let coordsets: Vec<Vec<u32>> = (0..n_reqs)
            .map(|_| {
                let t = 1 + rng.below(40);
                (0..t).map(|_| rng.below(d) as u32).collect()
            })
            .collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let reqs: Vec<PullRequest> = (0..n_reqs)
                .map(|i| PullRequest {
                    query: &queries[i],
                    rows: &rowsets[i],
                    coord_ids: &coordsets[i],
                })
                .collect();
            let mut solo = NativeEngine::default();
            let (mut s0, mut q0) = (Vec::new(), Vec::new());
            solo.pull_batch(&ds, &reqs, metric, &mut s0, &mut q0);
            for shards in 1..=8usize {
                let mut sharded =
                    ShardedEngine::new(NativeEngine::default(), shards);
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                sharded.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
                assert_eq!(s0, s1,
                           "pull sums n={n} shards={shards} {metric:?}");
                assert_eq!(q0, q1,
                           "pull sqs n={n} shards={shards} {metric:?}");
            }
        }
    }
}

#[test]
fn big_pull_batch_wave_crosses_the_parallel_threshold_bitwise() {
    // waves large enough that the pool actually dispatches (the small
    // tests above mostly exercise the inline path): 16 requests over all
    // rows with 256 coords each is ~1M coordinate ops per wave
    let n = 256;
    let d = 128;
    let ds = synthetic::gaussian_iid(n, d, 9);
    let mut rng = Rng::new(10);
    let n_reqs = 16;
    let queries: Vec<Vec<f32>> = (0..n_reqs)
        .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
        .collect();
    let rows_all: Vec<u32> = (0..n as u32).collect();
    let coordsets: Vec<Vec<u32>> = (0..n_reqs)
        .map(|_| (0..256).map(|_| rng.below(d) as u32).collect())
        .collect();
    for metric in [Metric::L2Sq, Metric::L1] {
        let reqs: Vec<PullRequest> = (0..n_reqs)
            .map(|i| PullRequest {
                query: &queries[i],
                rows: &rows_all,
                coord_ids: &coordsets[i],
            })
            .collect();
        let mut solo = NativeEngine::default();
        let (mut s0, mut q0) = (Vec::new(), Vec::new());
        solo.pull_batch(&ds, &reqs, metric, &mut s0, &mut q0);
        for shards in 1..=8usize {
            let mut sharded =
                ShardedEngine::new(NativeEngine::default(), shards);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            sharded.pull_batch(&ds, &reqs, metric, &mut s1, &mut q1);
            assert_eq!(s0, s1, "big wave sums shards={shards} {metric:?}");
            assert_eq!(q0, q1, "big wave sqs shards={shards} {metric:?}");
        }
    }
}

#[test]
fn parallel_path_with_fewer_rows_than_shards_bitwise() {
    // n = 4 dataset rows but a wave big enough to dispatch on the pool:
    // with 6-8 shards most shards own zero rows, and row-repeats pile
    // every job onto the few owners
    let n = 4;
    let d = 96;
    let ds = synthetic::gaussian_iid(n, d, 13);
    let mut rng = Rng::new(14);
    let query: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..4096).map(|i| (i % n) as u32).collect();
    let coords: Vec<u32> = (0..64).map(|_| rng.below(d) as u32).collect();
    for metric in [Metric::L2Sq, Metric::L1] {
        let mut solo = NativeEngine::default();
        let (mut s0, mut q0) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &query, &rows, &coords, metric, &mut s0,
                          &mut q0);
        for shards in [2usize, 6, 8] {
            let mut sharded =
                ShardedEngine::new(NativeEngine::default(), shards);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            sharded.partial_sums(&ds, &query, &rows, &coords, metric,
                                 &mut s1, &mut q1);
            assert_eq!(s0, s1, "n<S sums shards={shards} {metric:?}");
            assert_eq!(q0, q1, "n<S sqs shards={shards} {metric:?}");
        }
    }
}

#[test]
fn batched_knn_driver_is_bitwise_identical_under_sharding() {
    // end-to-end: the multi-query driver over a sharded engine must
    // produce byte-identical answers, distances and unit accounting —
    // the rng stream is outside the engine, so this holds exactly
    let ds = synthetic::image_like(150, 192, 55);
    let points: Vec<usize> = (0..12).map(|i| i * 11 % 150).collect();
    let params = BanditParams { k: 3, ..Default::default() };
    let mut solo_engine = NativeEngine::default();
    let mut rng0 = Rng::new(56);
    let mut c0 = Counter::new();
    let base = knn_batch_points_dense(&ds, &points, Metric::L2Sq, &params,
                                      &mut solo_engine, &mut rng0,
                                      &mut c0);
    for shards in [2usize, 3, 5] {
        let mut engine =
            ShardedEngine::new(NativeEngine::default(), shards);
        let mut rng = Rng::new(56);
        let mut c = Counter::new();
        let got = knn_batch_points_dense(&ds, &points, Metric::L2Sq,
                                         &params, &mut engine, &mut rng,
                                         &mut c);
        assert_eq!(c0.get(), c.get(), "units diverged at {shards} shards");
        for (b, g) in base.iter().zip(&got) {
            assert_eq!(b.ids, g.ids, "ids diverged at {shards} shards");
            assert_eq!(b.dists, g.dists,
                       "dists diverged at {shards} shards");
            assert_eq!(b.metrics.dist_computations,
                       g.metrics.dist_computations);
        }
    }
}
