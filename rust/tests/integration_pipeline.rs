//! Integration tests across the runtime boundary: PJRT artifacts vs host
//! engines, and the query server end to end. PJRT tests self-skip when
//! `make artifacts` has not been run.

use std::path::Path;

use bmonn::baselines::exact;
use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::knn_point_dense;
use bmonn::coordinator::server::{Client, Server, ServerConfig};
use bmonn::data::{synthetic, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::artifacts::Manifest;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::pjrt::{verify_exact_artifact, PjrtEngine, PjrtRuntime};
use bmonn::util::json::Json;
use bmonn::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn pjrt_exact_artifacts_match_host() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = PjrtRuntime::new(&dir).unwrap();
    for metric in [Metric::L2Sq, Metric::L1] {
        let rel = verify_exact_artifact(&mut rt, metric).unwrap();
        assert!(rel < 1e-3, "{metric:?}: rel err {rel}");
    }
}

#[test]
fn pjrt_engine_full_knn_query_matches_bruteforce() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let data = synthetic::image_like(300, 768, 21);
    let truth = exact::knn_point(&data, 0, 5, Metric::L2Sq,
                                 &mut Counter::new());
    let mut engine = PjrtEngine::new(&dir, Metric::L2Sq).unwrap();
    let mut params = BanditParams { k: 5, ..Default::default() };
    params.policy.round_pulls = engine.round_pulls();
    let mut rng = Rng::new(22);
    let mut c = Counter::new();
    let got = knn_point_dense(&data, 0, Metric::L2Sq, &params, &mut engine,
                              &mut rng, &mut c);
    let g: std::collections::HashSet<_> = got.ids.iter().collect();
    let w: std::collections::HashSet<_> = truth.ids.iter().collect();
    assert_eq!(g, w, "pjrt knn mismatch");
    assert!(engine.executions > 0, "pjrt was never exercised");
}

#[test]
fn pjrt_l1_engine_works() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let data = synthetic::image_like(200, 512, 23);
    let truth = exact::knn_point(&data, 1, 3, Metric::L1,
                                 &mut Counter::new());
    let mut engine = PjrtEngine::new(&dir, Metric::L1).unwrap();
    let mut params = BanditParams { k: 3, ..Default::default() };
    params.policy.round_pulls = engine.round_pulls();
    let mut rng = Rng::new(24);
    let mut c = Counter::new();
    let got = knn_point_dense(&data, 1, Metric::L1, &params, &mut engine,
                              &mut rng, &mut c);
    let g: std::collections::HashSet<_> = got.ids.iter().collect();
    let w: std::collections::HashSet<_> = truth.ids.iter().collect();
    assert_eq!(g, w);
}

#[test]
fn server_end_to_end_with_accuracy() {
    let data = synthetic::image_like(200, 256, 25);
    let queries: Vec<usize> = (0..10).collect();
    let truths: Vec<Vec<u32>> = queries
        .iter()
        .map(|&q| {
            exact::knn_query(&data, data.row(q), 3, Metric::L2Sq,
                             &mut Counter::new())
            .ids
        })
        .collect();
    let query_vecs: Vec<Vec<f32>> =
        queries.iter().map(|&q| data.row_vec(q)).collect();
    let mut srv = Server::start(
        data,
        ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .unwrap();
    let mut cl = Client::connect(&srv.addr).unwrap();
    for (qv, truth) in query_vecs.iter().zip(&truths) {
        let (ids, dists, units) = cl.knn(qv, 3).unwrap();
        assert!(units > 0);
        assert_eq!(ids.len(), 3);
        for w in dists.windows(2) {
            assert!(w[0] <= w[1] + 1e-6);
        }
        let g: std::collections::HashSet<_> = ids.iter().copied().collect();
        let w: std::collections::HashSet<_> =
            truth.iter().copied().collect();
        assert_eq!(g, w);
    }
    let stats = cl
        .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
        .unwrap();
    assert_eq!(stats.get("queries").unwrap().as_usize(), Some(10));
    srv.stop();
}

#[test]
fn native_and_scalar_engines_agree_end_to_end() {
    let data = synthetic::image_like(150, 512, 26);
    let run = |native: bool| -> Vec<u32> {
        let mut rng = Rng::new(27);
        let mut c = Counter::new();
        let p = BanditParams { k: 4, ..Default::default() };
        if native {
            let mut e = NativeEngine::default();
            knn_point_dense(&data, 0, Metric::L2Sq, &p, &mut e, &mut rng,
                            &mut c)
            .ids
        } else {
            let mut e = bmonn::coordinator::arms::ScalarEngine;
            knn_point_dense(&data, 0, Metric::L2Sq, &p, &mut e, &mut rng,
                            &mut c)
            .ids
        }
    };
    // identical rng stream + near-identical arithmetic -> same answer set
    let a = run(true);
    let b = run(false);
    let x: std::collections::HashSet<_> = a.iter().collect();
    let y: std::collections::HashSet<_> = b.iter().collect();
    assert_eq!(x, y);
}
