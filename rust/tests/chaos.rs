//! Deterministic chaos suite: seeded fault schedules over a replicated
//! shard ring, driven through the in-process fault-injection proxy
//! (`bmonn::runtime::fault`).
//!
//! The standing invariant under test:
//!
//!  * While every shard keeps at least one clean replica, a scripted
//!    storm of delays, mid-frame drops and single-byte corruptions on
//!    the primaries must produce **zero query errors** and answers
//!    **bitwise-identical** to solo `NativeEngine` — sub-waves fail
//!    over, the bandit never notices.
//!  * With a shard fully blackholed, every query must resolve within
//!    its deadline budget as a structured, classifiable error (never a
//!    hang), and a degraded-mode engine must answer coverage-annotated
//!    exact results over the surviving rows instead.
//!  * A partition scripted to heal at a fault epoch
//!    (`partition_until_epoch` + `advance_epoch`) must leave the ring
//!    bitwise-identical to solo again once healed.
//!  * Resharding mid-partition: while a shard of the old placement is
//!    partitioned (degraded coverage is the fallback), a transfer onto
//!    flapping staging targets fails cleanly without touching the old
//!    placement, a transfer onto healthy targets completes while the
//!    partition heals via `advance_epoch`, and the flip onto the new
//!    placement epoch is bitwise-identical to solo.
//!
//! Every random choice — the fault schedule and the query rng — derives
//! from a seed, so a failure reproduces exactly. CI sweeps a fixed seed
//! matrix; `BMONN_CHAOS_SEED=<u64>` pins a single seed for local
//! bisection.

use std::time::{Duration, Instant};

use bmonn::coordinator::bandit::BanditParams;
use bmonn::coordinator::knn::{knn_batch_dense_deadline, knn_point_dense,
                              KnnResult};
use bmonn::data::{synthetic, DenseDataset, Metric};
use bmonn::metrics::Counter;
use bmonn::runtime::fault::{Dir, FaultAction, FaultPlan, FaultProxy,
                            FaultRule};
use bmonn::runtime::kernels::KernelChoice;
use bmonn::runtime::native::NativeEngine;
use bmonn::runtime::placement::{PlacementMap, RetryPolicy};
use bmonn::runtime::remote::{reshard_to, spawn_loopback_ring,
                             RemoteEngine, RemoteOptions, RingClient,
                             ShardServer};
use bmonn::runtime::wire::is_deadline_error;
use bmonn::util::rng::Rng;

/// Seeds to sweep: `BMONN_CHAOS_SEED` pins one, else the CI matrix's
/// default trio.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("BMONN_CHAOS_SEED") {
        Ok(s) => vec![s.trim().parse()
            .expect("BMONN_CHAOS_SEED must be a u64")],
        Err(_) => vec![1, 7, 42],
    }
}

/// Short-timeout, fast-backoff options so blacklists heal within the
/// test's patience instead of the production default's.
fn fast_opts(degraded: bool, timeout: Duration) -> RemoteOptions {
    RemoteOptions {
        timeout: Some(timeout),
        degraded,
        retry: RetryPolicy {
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_millis(200),
        },
        ..RemoteOptions::default()
    }
}

/// `primary|replica` spec per shard.
fn replicated_specs(p_eps: &[String], r_eps: &[String]) -> Vec<String> {
    p_eps.iter().zip(r_eps).map(|(p, r)| format!("{p}|{r}")).collect()
}

/// Draw a scripted fault schedule from `rng`: ten rules spread over the
/// first forty frames of either direction, mixing short delays (well
/// under any timeout), mid-frame drops and single-byte corruptions.
fn scripted_plan(rng: &mut Rng) -> FaultPlan {
    let mut rules = Vec::new();
    for _ in 0..10 {
        let dir = if rng.below(2) == 0 {
            Dir::ToServer
        } else {
            Dir::ToClient
        };
        let frame = rng.below(40) as u64;
        let action = match rng.below(4) {
            0 => FaultAction::Delay(1 + rng.below(20) as u64),
            1 => FaultAction::DelayRange(1, 25),
            2 => FaultAction::DropMidFrame,
            _ => FaultAction::Corrupt,
        };
        rules.push(FaultRule { dir, frame, action });
    }
    FaultPlan { seed: rng.next_u64(), rules, ..Default::default() }
}

/// Reference answer from a solo in-process engine, rng seed `seed`.
fn solo_answer(ds: &DenseDataset, q: usize, params: &BanditParams,
               seed: u64) -> KnnResult {
    let mut solo = NativeEngine::default();
    let mut rng = Rng::new(seed);
    let mut c = Counter::new();
    knn_point_dense(ds, q, Metric::L2Sq, params, &mut solo, &mut rng,
                    &mut c)
}

#[test]
fn seeded_fault_schedules_with_live_replicas_stay_bitwise() {
    let ds = synthetic::gaussian_iid(60, 16, 51);
    let params = BanditParams { k: 5, delta: 0.01, ..Default::default() };
    for seed in chaos_seeds() {
        // primaries sit behind fault proxies; replicas are clean, so
        // every sub-wave has a healthy endpoint to fail over to
        let (_primaries, p_eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let (_replicas, r_eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let mut sched = Rng::new(seed);
        let proxies: Vec<FaultProxy> = p_eps
            .iter()
            .enumerate()
            .map(|(i, ep)| {
                FaultProxy::start(ep,
                                  scripted_plan(&mut sched.fork(i as u64)))
                    .unwrap()
            })
            .collect();
        let proxy_eps: Vec<String> =
            proxies.iter().map(|p| p.endpoint()).collect();
        let specs = replicated_specs(&proxy_eps, &r_eps);
        let mut eng = RemoteEngine::connect_opts(
            &PlacementMap::parse(&specs).unwrap(),
            fast_opts(false, Duration::from_secs(5)))
            .unwrap();
        for qi in 0..6usize {
            let qseed = seed.wrapping_add(qi as u64 * 101);
            let want = solo_answer(&ds, qi, &params, qseed);
            let got = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let mut rng = Rng::new(qseed);
                    let mut c = Counter::new();
                    knn_point_dense(&ds, qi, Metric::L2Sq, &params,
                                    &mut eng, &mut rng, &mut c)
                }));
            match got {
                Ok(res) => {
                    assert_eq!(res.ids, want.ids,
                               "seed {seed} query {qi}: ids diverged \
                                under faults");
                    assert_eq!(res.dists, want.dists,
                               "seed {seed} query {qi}: dists diverged \
                                under faults");
                }
                Err(e) => {
                    let msg = e.downcast_ref::<String>().cloned()
                        .unwrap_or_default();
                    panic!("seed {seed} query {qi}: query errored with a \
                            clean replica per shard: {msg}");
                }
            }
        }
        // the schedule must actually have been in the path: some
        // request traffic flowed through a proxied primary
        let fwd: u64 =
            proxies.iter().map(|p| p.frames(Dir::ToServer)).sum();
        assert!(fwd > 0,
                "seed {seed}: no frames crossed the fault proxies — \
                 the schedule was bypassed");
    }
}

#[test]
fn blackholed_shard_resolves_within_budget_or_degrades() {
    let ds = synthetic::gaussian_iid(60, 16, 31);
    let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let proxy =
        FaultProxy::start(&eps[1], FaultPlan::default()).unwrap();
    let specs = vec![eps[0].clone(), proxy.endpoint()];
    let params = BanditParams { k: 5, delta: 0.01, ..Default::default() };

    // --- degraded OFF, deadline budget ON: the 10s I/O window must
    // never be the bound — the query budget is ---------------------
    let mut eng = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(),
        fast_opts(false, Duration::from_secs(10)))
        .unwrap();
    // healthy ring first: bitwise parity through the idle proxy
    let want = solo_answer(&ds, 3, &params, 5);
    let res = {
        let mut rng = Rng::new(5);
        let mut c = Counter::new();
        knn_point_dense(&ds, 3, Metric::L2Sq, &params, &mut eng,
                        &mut rng, &mut c)
    };
    assert_eq!(res.ids, want.ids);
    assert_eq!(res.dists, want.dists);
    proxy.set_blackhole(true);
    let mut saw_deadline = false;
    for attempt in 0..3u64 {
        let start = Instant::now();
        let budget = start + Duration::from_millis(700);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(100 + attempt);
                let mut c = Counter::new();
                knn_batch_dense_deadline(&ds, &[ds.row_vec(0)],
                                         Metric::L2Sq, &params, &mut eng,
                                         &mut rng, &mut c, Some(budget))
            }))
            .expect_err("a blackholed shard with no replica must not \
                         produce an answer");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(is_deadline_error(&msg)
                    || msg.contains("remote pull wave failed")
                    || msg.contains("remote exact wave failed")
                    || msg.contains("no live replica"),
                "attempt {attempt}: unexpected panic payload: {msg}");
        saw_deadline |= is_deadline_error(&msg);
        // the structured failure must land promptly: bounded by the
        // 700ms budget (plus scheduling slack), not the 10s I/O window
        assert!(start.elapsed() < Duration::from_secs(5),
                "attempt {attempt}: query took {:?} — the deadline \
                 budget did not cut the wait", start.elapsed());
    }
    assert!(saw_deadline,
            "no attempt was classified as a deadline error");

    // --- degraded ON: coverage-annotated exact answers over the
    // surviving rows, still prompt ---------------------------------
    let mut eng = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(),
        fast_opts(true, Duration::from_millis(500)))
        .unwrap();
    let start = Instant::now();
    let res = {
        let mut rng = Rng::new(8);
        let mut c = Counter::new();
        knn_point_dense(&ds, 3, Metric::L2Sq, &params, &mut eng,
                        &mut rng, &mut c)
    };
    let cov = res.coverage
        .expect("degraded answer must carry a coverage annotation");
    assert_eq!(cov.rows_total, 60);
    assert!(cov.rows_live() > 0 && cov.fraction() < 1.0,
            "coverage must reflect the dead shard: {cov:?}");
    // shard 1 holds rows [30, 60): every answer id must be a survivor
    for &id in &res.ids {
        assert!(id < 30,
                "answer id {id} lies in the blackholed shard's rows");
    }
    assert!(start.elapsed() < Duration::from_secs(8),
            "degraded answer took {:?}", start.elapsed());
}

#[test]
fn partitioned_shard_heals_on_epoch_advance_bitwise() {
    let ds = synthetic::gaussian_iid(60, 16, 41);
    let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
    let proxy = FaultProxy::start(
        &eps[1],
        FaultPlan { partition_until_epoch: Some(1),
                    ..Default::default() })
        .unwrap();
    let specs = vec![eps[0].clone(), proxy.endpoint()];
    let params = BanditParams { k: 5, delta: 0.01, ..Default::default() };
    let mut eng = RemoteEngine::connect_opts(
        &PlacementMap::parse(&specs).unwrap(),
        fast_opts(true, Duration::from_millis(500)))
        .unwrap();
    let want = solo_answer(&ds, 7, &params, 9);
    // partitioned: the degraded engine answers over shard 0 only
    let res = {
        let mut rng = Rng::new(9);
        let mut c = Counter::new();
        knn_point_dense(&ds, 7, Metric::L2Sq, &params, &mut eng,
                        &mut rng, &mut c)
    };
    let cov = res.coverage
        .expect("partitioned ring must answer degraded");
    assert!(cov.fraction() < 1.0);
    // script the heal: epoch 1 reaches partition_until_epoch, so the
    // proxy starts forwarding fresh connections upstream
    assert_eq!(proxy.advance_epoch(), 1);
    // the client redials once the endpoint's blacklist backoff expires
    // (<= 200ms with fast_opts); poll until full coverage returns, then
    // the answer must be bitwise-identical to solo again
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let res = {
            let mut rng = Rng::new(9);
            let mut c = Counter::new();
            knn_point_dense(&ds, 7, Metric::L2Sq, &params, &mut eng,
                            &mut rng, &mut c)
        };
        if res.coverage.is_none() {
            assert_eq!(res.ids, want.ids,
                       "healed ring must be bitwise-identical to solo");
            assert_eq!(res.dists, want.dists);
            break;
        }
        assert!(Instant::now() < deadline,
                "ring did not heal within 10s of the epoch advance");
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Start `n` empty staging servers on loopback ephemeral ports.
fn staging_ring(n: usize) -> (Vec<ShardServer>, Vec<String>) {
    let mut servers = Vec::with_capacity(n);
    let mut eps = Vec::with_capacity(n);
    for _ in 0..n {
        let s = ShardServer::start_staging("127.0.0.1:0",
                                           KernelChoice::Auto,
                                           Some(Duration::from_secs(5)))
            .expect("staging server");
        eps.push(s.endpoint());
        servers.push(s);
    }
    (servers, eps)
}

#[test]
fn reshard_mid_partition_heals_and_flips_bitwise() {
    let ds = synthetic::gaussian_iid(60, 16, 61);
    let params = BanditParams { k: 5, delta: 0.01, ..Default::default() };
    for seed in chaos_seeds() {
        // old placement: 2 shards, shard 1 partitioned until fault
        // epoch 1 — mid-partition, degraded coverage is the fallback
        let (_old_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let proxy = FaultProxy::start(
            &eps[1],
            FaultPlan { partition_until_epoch: Some(1),
                        ..Default::default() })
            .unwrap();
        let specs = vec![eps[0].clone(), proxy.endpoint()];
        let mut eng = RemoteEngine::connect_opts(
            &PlacementMap::parse(&specs).unwrap(),
            fast_opts(true, Duration::from_millis(500)))
            .unwrap();
        let qseed = seed.wrapping_add(7);
        let res = {
            let mut rng = Rng::new(qseed);
            let mut c = Counter::new();
            knn_point_dense(&ds, 7, Metric::L2Sq, &params, &mut eng,
                            &mut rng, &mut c)
        };
        let cov = res.coverage
            .expect("partitioned ring must answer degraded");
        assert!(cov.fraction() < 1.0);
        // attempt 1: the transfer targets sit behind a seeded fault
        // schedule plus a guaranteed mid-chunk severance — the reshard
        // must fail cleanly, and the old (partitioned, degraded)
        // placement must keep serving untouched
        let mut sched = Rng::new(seed);
        let (_flappy, f_eps) = staging_ring(2);
        let flappy_proxies: Vec<FaultProxy> = f_eps
            .iter()
            .enumerate()
            .map(|(i, ep)| {
                let mut plan =
                    scripted_plan(&mut sched.fork(i as u64));
                plan.rules.push(FaultRule {
                    dir: Dir::ToServer,
                    frame: 1,
                    action: FaultAction::DropMidFrame,
                });
                FaultProxy::start(ep, plan).unwrap()
            })
            .collect();
        let f_specs: Vec<String> =
            flappy_proxies.iter().map(|p| p.endpoint()).collect();
        let err = reshard_to(&ds, &PlacementMap::parse(&f_specs).unwrap(),
                             1, Some(Duration::from_secs(5)))
            .expect_err("a severed transfer stream must fail the \
                         reshard");
        assert!(!err.is_empty());
        let res = {
            let mut rng = Rng::new(qseed);
            let mut c = Counter::new();
            knn_point_dense(&ds, 7, Metric::L2Sq, &params, &mut eng,
                            &mut rng, &mut c)
        };
        assert!(res.coverage.is_some(),
                "seed {seed}: the failed reshard must leave the old \
                 placement serving (degraded, but answering)");
        // attempt 2: healthy targets; the partition heals via
        // advance_epoch while this transfer is in flight
        let (_staged, new_eps) = staging_ring(4);
        let new_map = PlacementMap::parse(&new_eps).unwrap();
        let fps = std::thread::scope(|sc| {
            let h = sc.spawn(|| {
                reshard_to(&ds, &new_map, 1,
                           Some(Duration::from_secs(5)))
            });
            assert_eq!(proxy.advance_epoch(), 1);
            h.join().expect("transfer thread")
        })
        .expect("reshard onto healthy staging servers");
        assert_eq!(fps.len(), 4);
        // flip: an epoch-pinned client on the new placement answers
        // bitwise-identical to solo
        let client = RingClient::connect_opts(
            &new_map,
            RemoteOptions {
                timeout: Some(Duration::from_secs(5)),
                expect_epoch: Some(1),
                ..RemoteOptions::default()
            })
            .expect("connect to the resharded ring");
        assert_eq!(client.epoch(), 1);
        let mut fresh =
            RemoteEngine::from_client(std::sync::Arc::new(client));
        for qi in 0..4usize {
            let s = seed.wrapping_add(qi as u64 * 131);
            let want = solo_answer(&ds, qi, &params, s);
            let got = {
                let mut rng = Rng::new(s);
                let mut c = Counter::new();
                knn_point_dense(&ds, qi, Metric::L2Sq, &params,
                                &mut fresh, &mut rng, &mut c)
            };
            assert_eq!(got.ids, want.ids,
                       "seed {seed} query {qi}: post-flip ids diverged");
            assert_eq!(got.dists, want.dists,
                       "seed {seed} query {qi}: post-flip dists \
                        diverged");
        }
        // and the healed old placement returns to full coverage — the
        // epoch advance reached it while the transfer streamed
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let res = {
                let mut rng = Rng::new(qseed);
                let mut c = Counter::new();
                knn_point_dense(&ds, 7, Metric::L2Sq, &params, &mut eng,
                                &mut rng, &mut c)
            };
            if res.coverage.is_none() {
                break;
            }
            assert!(Instant::now() < deadline,
                    "seed {seed}: old ring did not heal within 10s of \
                     the epoch advance");
            std::thread::sleep(Duration::from_millis(100));
        }
    }
}
