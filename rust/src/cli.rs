//! Command-line interface (hand-rolled; no clap in the offline crate set).
//!
//! Subcommands:
//!   gen-data   — write synthetic datasets to .bmd/.bms files
//!   knn        — k-NN queries over a dataset (bandit or baselines)
//!   graph      — full k-NN graph construction
//!   kmeans     — BMO k-means vs exact Lloyd's
//!   serve      — start the query server
//!   shard-serve— serve one row shard of a dataset to remote coordinators
//!   ring-stats — probe a shard-serve ring's health via the Stats wire op
//!   reshard    — stream a dataset onto a new ring of staging servers
//!   bench      — run a figure-reproduction experiment (fig3a, fig3b, ...)
//!   selftest   — verify PJRT artifacts against host computation

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, --key value flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags are `--key value` or `--key=value`;
    /// a bare `--key` followed by another flag (or end) is "true".
    pub fn parse<I: IntoIterator<Item = String>>(argv: I)
                 -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        let mut args = Args {
            subcommand: it.next().unwrap_or_default(),
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        args.flags
                            .insert(flag.to_string(), it.next().unwrap());
                    } else {
                        args.flags.insert(flag.to_string(), "true".into());
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_usize(&self, name: &str, default: usize)
                      -> Result<usize, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse()
                .map_err(|_| format!("--{name}: bad usize '{v}'")),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| format!("--{name}: bad u64 '{v}'"))
            }
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| format!("--{name}: bad f64 '{v}'"))
            }
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

pub const USAGE: &str = "\
bmonn — Bandit-based Monte Carlo Optimization for Nearest Neighbors

USAGE: bmonn <subcommand> [--flags]

SUBCOMMANDS
  gen-data --kind image|rna|gaussian|powerlaw --n N --d D --out FILE
           [--seed S] [--density F] [--alpha A]
  knn      --data FILE [--query-idx I] [--k K] [--batch B] [--algo bmo|
           exact|lsh|kgraph|ngt|uniform] [--metric l2|l1] [--engine
           native|scalar|pjrt] [--shards S] [--remote SPECS]
           [--degraded] [--kernel auto|scalar|avx2|neon] [--quantized]
           [--speculate] [--epsilon E] [--delta D] [--seed S]
           [--io-timeout-ms T]
           (--batch B > 1 answers B consecutive query points through the
           coalesced multi-query driver, bmo only; --shards S > 1 fans
           each pull wave across S contiguous row shards on a worker
           pool; --remote fans waves over a shard-serve ring instead —
           either way results are bitwise-identical to local
           single-threaded execution. SPECS is one entry per shard,
           comma-separated; an entry may be a |-separated replica list
           (H:P|H:P) and sub-waves fail over between a shard's replicas
           transparently. --degraded answers with exact distances over
           the surviving rows — coverage-annotated — when every replica
           of some shard is dead, instead of erroring. --kernel forces a
           row-kernel tier for the native engine instead of the auto
           CPU-feature dispatch; forcing a tier this host lacks is a
           startup error. --quantized samples from an int8 shadow copy
           and rescores candidates on exact f32, widening confidence
           intervals by the quantization error bound; local engines
           only. With --remote, pass --kernel to shard-serve instead —
           both tune the process doing the computing. --speculate
           overlaps round t+1's predicted pull wave with round t's
           retirement on pipelined (remote) engines — answers stay
           bitwise-identical, mispredicted waves are abandoned without
           spending failover attempts or deadline budget; local
           blocking engines ignore it. --io-timeout-ms bounds the ring
           client's connects, writes and per-wave reply waits, default
           60000)
  graph    --data FILE [--k K] [--metric l2|l1] [--shards S]
           [--remote SPECS] [--degraded] [--kernel T] [--quantized]
           [--seed S] [--io-timeout-ms T]
  kmeans   --data FILE [--clusters K] [--iters I] [--algo bmo|exact]
  serve    --data FILE [--addr HOST:PORT] [--config FILE] [--shards S]
           [--remote SPECS] [--degraded] [--kernel T] [--quantized]
           [--speculate] [--batch-wait-us T] [--deadline-ms D]
           [--max-queue Q] [--io-timeout-ms T] [--http-port P]
           [--cache-entries N]
           (with --remote this box coordinates a multi-machine ring: all
           workers share ONE multiplexed ring client — one connection
           per shard, concurrent tagged waves interleaved on it — so
           independent batches overlap on the wire; sub-waves fail over
           between replicas; with --degraded, knn responses gain
           coverage/rows_live/rows_total fields while part of the ring
           is down, instead of turning into errors; workers reconnect
           if a whole shard dies. --batch-wait-us T lets a worker that
           drained a non-full batch linger T microseconds for more
           queries — fuller batches under light load, observable via
           stats mean_batch/max_batch. --deadline-ms D gives every query
           an answer-by budget of D milliseconds from arrival — queue
           wait, lockstep rounds and remote waves all charge against it
           and an expired query gets a structured deadline_exceeded
           error, never a hung worker; a request-level deadline_ms JSON
           field overrides it per query. --max-queue Q sheds queries
           arriving at a full queue with an overload error carrying a
           retry_after_ms hint. Shed / expired counts surface via
           stats. Both default to 0 = off. --http-port P adds an
           HTTP/1.1 front door on the same host: POST /knn speaks the
           knn request body through the same validation, deadline and
           admission path with real status codes — 200 ok, 400 bad
           request, 429 overload with Retry-After, 504 deadline — and
           GET /metrics returns the stats body; P=0 binds an ephemeral
           port. --cache-entries N enables an N-entry LRU result cache
           keyed on query/k/accuracy mode/dataset fingerprint/placement
           epoch: repeat queries replay byte-identical answers without
           touching the bandit, and the epoch-bump op [POST
           /admin/epoch-bump] invalidates every cached answer after a
           dataset or placement change. Hits/misses surface via stats.
           --speculate turns on cross-round wave pipelining for
           --remote rings: workers overlap each round's retirement with
           the next round's predicted wave, abandoning mispredictions;
           answers are bitwise-identical either way, and speculated /
           confirmed / discarded wave counts surface via stats and
           GET /metrics)
  shard-serve  (--data FILE | --synthetic image:N:D:SEED | --staging)
           --shard I --of S [--addr HOST:PORT]
           [--kernel auto|scalar|avx2|neon] [--epoch E]
           [--io-timeout-ms T]
           (loads rows [floor(I*n/S), floor((I+1)*n/S)) — the same
           floor-boundary partition --shards uses — and answers
           partial_sums / exact_dists / pull_batch waves over the
           length-prefixed binary wire protocol [runtime::wire]; a ring
           of S such servers, shard indices 0..S on matching endpoints,
           backs --remote, and starting shard I on several machines
           makes them replicas; a shutdown frame or ctrl-c stops it.
           --kernel forces this server's row-kernel tier — keep it
           identical across a ring's replicas, or failover between
           them may change float rounding; --epoch E stamps E into the
           handshake as this server's placement epoch (default 0) —
           every endpoint of one placement must carry one epoch;
           --staging starts the server EMPTY: it answers queries with
           an error until a reshard/transfer installs a
           fingerprint-verified dataset (and its epoch) over the wire,
           then serves exactly like a --data server. --io-timeout-ms
           bounds its reply writes, default 60000)
  ring-stats  --remote SPECS [--io-timeout-ms T] [--timeout-ms T]
           (probes every endpoint with the Stats wire op and prints
           shard identity, row range, dataset shape, dataset
           fingerprint, live-connection count and the per-connection
           concurrent-wave high-water mark per replica, plus ring
           coverage; exits nonzero when some shard has no live replica
           OR when a shard's replicas report divergent dataset
           fingerprints (failover between them would change answers).
           The reported of-value from any single endpoint tells you
           the ring size S, so a coordinator can size --remote from
           one known endpoint; each endpoint's placement epoch is
           printed too, and divergent epochs across the ring also
           exit nonzero)
  reshard  --data FILE --to SPECS [--epoch E] [--io-timeout-ms T]
           (streams FILE's rows onto a new placement of STAGING shard
           servers — SPECS is one entry per shard, comma-separated,
           each optionally a |-separated replica list, every endpoint
           started with shard-serve --staging — verifying each
           installed shard against wire::dataset_fingerprint before it
           can serve, and stamps placement epoch E [default 1] into
           the new ring. A running query server does this live via the
           reshard op / POST /admin/reshard instead, which also flips
           its workers onto the new ring and auto-bumps the result
           cache epoch; this subcommand only populates the servers)
  bench    <fig3a|fig3b|fig4a|fig4b|fig4c|fig5|fig7|prop1|cor1|thm1|pull>
           [--quick] [--seed S] [--out FILE] [--shards S]
           (--shards fans the figure benches' BMO runs out across S row
           shards; pull rejects it — it is the tracked pull-phase
           throughput baseline, always sweeping a fixed 1/2/4 shard
           ladder over the 1k x 256 batched workload plus a single-query
           sweep, a 2-shard TCP-loopback remote rung, a 2-shard
           failover rung (replicated ring with every primary dead, so
           each wave takes the failover path) and a 2-shard multiplex
           rung (two concurrent batch drivers sharing one ring client;
           asserts >= 2 waves in flight on one connection), a 2-shard
           tcp-deadline rung and an http-front rung (a saturation burst
           against a max_queue=1 HTTP front door over a loopback ring:
           clean 429s, nonzero byte-identical cache hits, bounded p99),
           overwriting
           --out [default BENCH_pull.json] with rows/s, wall per round
           and per-query p50/p99; --smoke shrinks it to a seconds-long
           CI check; --remote H:P,H:P adds a rung measured against your
           own ring, whose servers must load the bench dataset, e.g.
           shard-serve --synthetic image:1000:256:SEED for the full
           ladder or image:256:64:SEED for --smoke)
  selftest [--artifacts DIR]

Common flags: --config FILE (TOML; [engine] kind/shards/remote/degraded/
kernel/quantized/speculate/epoch/io_timeout_ms pick and tune the pull
engine,
[server] deadline_ms/max_queue/batch_wait_us/http_port/cache_entries
shape the query server — see docs/CONFIG.md and docs/OPERATIONS.md),
--set section.key=value (repeatable via comma list), --seed N.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(sv(&[
            "knn", "--data", "x.bmd", "--k", "5", "--quick",
            "--delta=0.01",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "knn");
        assert_eq!(a.flag("data"), Some("x.bmd"));
        assert_eq!(a.flag_usize("k", 1).unwrap(), 5);
        assert!(a.flag_bool("quick"));
        assert_eq!(a.flag_f64("delta", 0.1).unwrap(), 0.01);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(sv(&["bench", "fig3a"])).unwrap();
        assert_eq!(a.positional, vec!["fig3a"]);
        assert_eq!(a.flag_usize("k", 7).unwrap(), 7);
        let b = Args::parse(sv(&["knn", "--k", "abc"])).unwrap();
        assert!(b.flag_usize("k", 1).is_err());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(sv(&[])).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
