//! Figure runners: one function per paper figure/claim.
//!
//! All runners are deterministic given `seed`, print the same series the
//! paper reports (gain over exact computation in coordinate-wise distance
//! computations, plus accuracy), and return a [`Report`]. `quick` shrinks
//! workload sizes ~4-10x for CI and `cargo bench` smoke runs.

use crate::baselines::graph_search::{AnngIndex, AnngParams};
use crate::baselines::nndescent::{NnDescentIndex, NnDescentParams};
use crate::baselines::{exact, uniform};
use crate::bench_harness::{fmt_f, fmt_gain, set_accuracy, Report};
use crate::config::EngineKind;
use crate::coordinator::bandit::{BanditParams, PullPolicy, SigmaMode};
use crate::coordinator::kmeans::{kmeans_bmo, kmeans_exact, KMeansParams};
use crate::coordinator::knn::{knn_batch_points_dense, knn_batch_sparse,
                              knn_point_dense};
use crate::coordinator::pac;
use crate::data::dense::{DenseDataset, Metric};
use crate::data::rotate::Rotation;
use crate::data::synthetic;
use crate::metrics::{Counter, Histogram};
use crate::runtime::native::NativeEngine;
use crate::util::rng::Rng;

fn bmo_params(k: usize) -> BanditParams {
    BanditParams { k, delta: 0.01, sigma: SigmaMode::Empirical,
                   epsilon: 0.0, policy: PullPolicy::batched(),
                   bias: 0.0 }
}

/// Per-algorithm stats over a set of queries.
struct AlgoStats {
    units: u64,
    answers: Vec<Vec<u32>>,
}

struct Workload {
    data: DenseDataset,
    queries: Vec<usize>,
    k: usize,
    truth: Vec<Vec<u32>>,
    exact_units_per_query: u64,
}

fn make_workload(n: usize, d: usize, k: usize, n_queries: usize, seed: u64)
                 -> Workload {
    let data = synthetic::image_like(n, d, seed);
    let mut rng = Rng::new(seed ^ 0x9999);
    let queries: Vec<usize> =
        (0..n_queries).map(|_| rng.below(n)).collect();
    let truth = queries
        .iter()
        .map(|&q| {
            exact::knn_point(&data, q, k, Metric::L2Sq, &mut Counter::new())
                .ids
        })
        .collect();
    Workload {
        exact_units_per_query: ((n - 1) * d) as u64,
        data,
        queries,
        k,
        truth,
    }
}

fn run_bmo(w: &Workload, seed: u64, shards: usize) -> AlgoStats {
    // the whole query set runs through the batched multi-query driver —
    // the same coalesced path the server uses; shards > 1 additionally
    // fans each round's pull wave across a row-sharded worker pool
    // (answers are bitwise-independent of the shard count)
    let mut engine = crate::runtime::build_host_engine(
        EngineKind::Native, shards, &[], false,
        crate::runtime::kernels::KernelChoice::Auto, false, false, None)
        .expect("native host engine");
    let mut rng = Rng::new(seed);
    let mut c = Counter::new();
    let params = bmo_params(w.k);
    let answers = knn_batch_points_dense(&w.data, &w.queries, Metric::L2Sq,
                                         &params, &mut engine, &mut rng,
                                         &mut c)
        .into_iter()
        .map(|r| r.ids)
        .collect();
    AlgoStats { units: c.get(), answers }
}

fn run_lsh(w: &Workload, seed: u64) -> AlgoStats {
    let mut rng = Rng::new(seed);
    let (idx, _p) = crate::baselines::lsh::build_tuned(
        &w.data, Metric::L2Sq, w.k, 0.95, &mut rng);
    let mut c = Counter::new();
    let answers = w
        .queries
        .iter()
        .map(|&q| {
            idx.knn_query(w.data.row(q), Some(q), w.k, &mut c)
                .into_iter()
                .map(|(i, _)| i)
                .collect()
        })
        .collect();
    AlgoStats { units: c.get(), answers }
}

fn run_kgraph(w: &Workload, seed: u64) -> AlgoStats {
    let mut rng = Rng::new(seed);
    let idx = NnDescentIndex::build(&w.data, Metric::L2Sq,
                                    NnDescentParams::default(), &mut rng);
    let mut c = Counter::new();
    let answers = w
        .queries
        .iter()
        .map(|&q| {
            idx.knn_query(w.data.row(q), Some(q), w.k, &mut rng, &mut c)
                .into_iter()
                .map(|(i, _)| i)
                .collect()
        })
        .collect();
    AlgoStats { units: c.get(), answers }
}

fn run_ngt(w: &Workload, seed: u64) -> AlgoStats {
    let mut rng = Rng::new(seed);
    let idx = AnngIndex::build(&w.data, Metric::L2Sq,
                               AnngParams::default(), &mut rng);
    let mut c = Counter::new();
    let answers = w
        .queries
        .iter()
        .map(|&q| {
            idx.knn_query(w.data.row(q), Some(q), w.k, &mut rng, &mut c)
                .into_iter()
                .map(|(i, _)| i)
                .collect()
        })
        .collect();
    AlgoStats { units: c.get(), answers }
}

fn gain_row(label: String, w: &Workload, stats: &AlgoStats) -> Vec<String> {
    let exact_total = w.exact_units_per_query * w.queries.len() as u64;
    vec![
        label,
        fmt_gain(exact_total as f64 / stats.units.max(1) as f64),
        fmt_f(set_accuracy(&stats.answers, &w.truth), 3),
        format!("{}", stats.units / w.queries.len() as u64),
    ]
}

/// Fig 3(a): gain vs number of points n (d fixed).
pub fn fig3a(quick: bool, seed: u64, shards: usize) -> Report {
    let (d, k, nq) = if quick { (512, 5, 8) } else { (2048, 5, 16) };
    let ns: &[usize] = if quick { &[200, 400, 800] }
                       else { &[500, 1000, 2000, 4000] };
    let mut rep = Report::new(
        "Fig 3(a): gain in coordinate-ops vs exact, varying n",
        &["n", "algo", "gain", "accuracy", "units/query"]);
    for &n in ns {
        let w = make_workload(n, d, k, nq, seed);
        for (name, stats) in [
            ("BMO-NN", run_bmo(&w, seed + 1, shards)),
            ("LSH", run_lsh(&w, seed + 2)),
            ("kGraph", run_kgraph(&w, seed + 3)),
            ("NGT", run_ngt(&w, seed + 4)),
        ] {
            let r = gain_row(name.to_string(), &w, &stats);
            rep.row(vec![n.to_string(), r[0].clone(), r[1].clone(),
                         r[2].clone(), r[3].clone()]);
        }
    }
    rep.note("paper: BMO-NN gain ~flat in n; graph methods gain with n");
    rep
}

/// Fig 2 / Fig 3(b): gain vs dimension d (n fixed).
pub fn fig3b(quick: bool, seed: u64, shards: usize) -> Report {
    let (n, k, nq) = if quick { (400, 5, 8) } else { (2000, 5, 16) };
    let ds: &[usize] = if quick { &[128, 256, 512, 1024] }
                       else { &[256, 512, 1024, 2048, 4096] };
    let mut rep = Report::new(
        "Fig 2 / Fig 3(b): gain in coordinate-ops vs exact, varying d",
        &["d", "algo", "gain", "accuracy", "units/query"]);
    for &d in ds {
        let w = make_workload(n, d, k, nq, seed);
        for (name, stats) in [
            ("BMO-NN", run_bmo(&w, seed + 1, shards)),
            ("LSH", run_lsh(&w, seed + 2)),
            ("kGraph", run_kgraph(&w, seed + 3)),
            ("NGT", run_ngt(&w, seed + 4)),
        ] {
            let r = gain_row(name.to_string(), &w, &stats);
            rep.row(vec![d.to_string(), r[0].clone(), r[1].clone(),
                         r[2].clone(), r[3].clone()]);
        }
    }
    rep.note("paper: BMO-NN gain grows ~linearly with d; \
              graph/LSH gains flat in d");
    rep
}

/// Fig 4(a): non-adaptive sampling accuracy at multiples of BMO's budget.
pub fn fig4a(quick: bool, seed: u64, shards: usize) -> Report {
    let (n, d, k, nq) = if quick { (300, 512, 1, 10) }
                        else { (1000, 2048, 1, 20) };
    let w = make_workload(n, d, k, nq, seed);
    let bmo = run_bmo(&w, seed + 1, shards);
    let bmo_acc = set_accuracy(&bmo.answers, &w.truth);
    let mut rep = Report::new(
        "Fig 4(a): non-adaptive uniform sampling at x times BMO's budget",
        &["budget multiple", "algo", "accuracy"]);
    rep.row(vec!["1".into(), "BMO-NN".into(), fmt_f(bmo_acc, 3)]);
    let mut rng = Rng::new(seed + 5);
    for mult in [1u64, 2, 5, 10, 20, 40, 80] {
        let acc = uniform::accuracy_at_budget(
            &w.data, &w.queries, k, Metric::L2Sq, bmo.units * mult,
            &mut rng);
        rep.row(vec![mult.to_string(), "uniform".into(), fmt_f(acc, 3)]);
    }
    rep.note("paper: uniform sampling has poor accuracy even at 80x \
              BMO's sample budget");
    rep
}

/// Fig 4(b): sparse dataset gains (ℓ1, sparse MC box vs sparse-aware
/// exact; dense box shown for contrast).
pub fn fig4b(quick: bool, seed: u64) -> Report {
    // nnz/row must be large enough that adaptive sampling has headroom
    // below the sparse-exact cost (paper: d=28k, ~2k nnz/row)
    let (n, d, dens, k, nq) = if quick { (200, 16384, 0.07, 5, 6) }
                              else { (500, 28000, 0.07, 5, 10) };
    let data = synthetic::rna_like(n, d, dens, seed);
    let mut rng = Rng::new(seed ^ 0xAAAA);
    let queries: Vec<usize> = (0..nq).map(|_| rng.below(n)).collect();
    // sparse-aware exact baseline
    let mut c_exact = Counter::new();
    let truth: Vec<Vec<u32>> = queries
        .iter()
        .map(|&q| exact::knn_point_sparse(&data, q, k, Metric::L1,
                                          &mut c_exact).ids)
        .collect();
    // BMO with the sparse MC box, through the batched lockstep driver
    let mut c_bmo = Counter::new();
    let params = bmo_params(k);
    let got: Vec<Vec<u32>> =
        knn_batch_sparse(&data, &queries, Metric::L1, &params, &mut rng,
                         &mut c_bmo)
            .into_iter()
            .map(|r| r.ids)
            .collect();
    // dense-box-on-sparse-data contrast (what §IV-A warns against):
    // the dense estimator wastes samples on zero coordinates
    let dense_data = data.to_dense();
    let mut c_dense = Counter::new();
    let mut engine = NativeEngine::default();
    let got_dense: Vec<Vec<u32>> =
        knn_batch_points_dense(&dense_data, &queries, Metric::L1, &params,
                               &mut engine, &mut rng, &mut c_dense)
            .into_iter()
            .map(|r| r.ids)
            .collect();
    let mut rep = Report::new(
        "Fig 4(b): sparse gene-like dataset (l1), gain vs sparse-aware exact",
        &["algo", "gain vs sparse-exact", "accuracy", "units/query"]);
    let nqq = queries.len() as u64;
    rep.row(vec![
        "BMO sparse box".into(),
        fmt_gain(c_exact.get() as f64 / c_bmo.get().max(1) as f64),
        fmt_f(set_accuracy(&got, &truth), 3),
        format!("{}", c_bmo.get() / nqq),
    ]);
    rep.row(vec![
        "BMO dense box".into(),
        fmt_gain(c_exact.get() as f64 / c_dense.get().max(1) as f64),
        fmt_f(set_accuracy(&got_dense, &truth), 3),
        format!("{}", c_dense.get() / nqq),
    ]);
    rep.row(vec![
        "sparse exact".into(),
        "1.0x".into(),
        "1.000".into(),
        format!("{}", c_exact.get() / nqq),
    ]);
    rep.note(&format!("density {:.3}; paper: ~3x gain for the sparse box, \
                       no gain for the dense box", data.density()));
    rep
}

/// Fig 4(c): coordinate-wise distance histograms, dense vs sparse data.
pub fn fig4c(quick: bool, seed: u64) -> Report {
    let (n, d) = if quick { (100, 512) } else { (400, 2048) };
    let dense = synthetic::image_like(n, d, seed);
    let sparse = synthetic::rna_like(n, d, 0.07, seed + 1).to_dense();
    let mut rng = Rng::new(seed + 2);
    let mut rep = Report::new(
        "Fig 4(c): histogram of coordinate-wise distances (random pairs)",
        &["dataset", "mean", "p99", "max", "tail>4*mean", "histogram"]);
    for (name, ds, metric) in [
        ("image-like (l2^2 coords)", &dense, Metric::L2Sq),
        ("rna-like (l1 coords)", &sparse, Metric::L1),
    ] {
        let mut h = Histogram::new(0.0, 1.0, 40);
        // sample raw coordinate distances over random pairs
        let mut samples: Vec<f64> = Vec::new();
        for _ in 0..200 {
            let i = rng.below(n);
            let mut j = rng.below(n);
            while j == i {
                j = rng.below(n);
            }
            let (ri, rj) = (ds.row(i), ds.row(j));
            for _ in 0..64 {
                let c = rng.below(d);
                samples.push(metric.coord(ri[c], rj[c]) as f64);
            }
        }
        let max = samples.iter().cloned().fold(0.0, f64::max);
        let mut hist = Histogram::new(0.0, max.max(1e-12), 40);
        for &s in &samples {
            hist.record(s);
        }
        let mean = hist.mean();
        let tail = samples.iter().filter(|&&s| s > 4.0 * mean).count()
            as f64 / samples.len() as f64;
        rep.row(vec![
            name.into(),
            fmt_f(mean, 4),
            fmt_f(hist.quantile(0.99), 4),
            fmt_f(max, 4),
            fmt_f(tail, 4),
            hist.sparkline(),
        ]);
        let _ = &mut h;
    }
    rep.note("paper: coordinate distances have rapidly decaying tails, \
              supporting the sub-Gaussian assumption");
    rep
}

/// Fig 5: BMO k-means gain over exact Lloyd's.
pub fn fig5(quick: bool, seed: u64) -> Report {
    let (n, d, kc) = if quick { (300, 2048, 24) } else { (1000, 4096, 100) };
    let data = synthetic::image_like(n, d, seed);
    let params = KMeansParams {
        k: kc,
        max_iters: if quick { 4 } else { 6 },
        ..Default::default()
    };
    let mut engine = NativeEngine::default();
    let mut rng1 = Rng::new(seed + 1);
    let bmo = kmeans_bmo(&data, &params, &mut engine, &mut rng1);
    let mut rng2 = Rng::new(seed + 1);
    let ex = kmeans_exact(&data, &params, &mut rng2);
    let mut rep = Report::new(
        "Fig 5: k-means assignment-step gain (BMO vs exact Lloyd's)",
        &["algo", "units/iter", "gain", "assign accuracy", "iters"]);
    let bmo_per = bmo.metrics.dist_computations / bmo.iters as u64;
    let ex_per = ex.metrics.dist_computations / ex.iters as u64;
    rep.row(vec![
        format!("BMO k-means (k={kc})"),
        bmo_per.to_string(),
        fmt_gain(ex_per as f64 / bmo_per.max(1) as f64),
        fmt_f(*bmo.assign_accuracy.last().unwrap_or(&0.0), 3),
        bmo.iters.to_string(),
    ]);
    rep.row(vec![
        "exact Lloyd's".into(),
        ex_per.to_string(),
        "1.0x".into(),
        "1.000".into(),
        ex.iters.to_string(),
    ]);
    rep.note("paper: 30-50x gain at k=100, d=12288, accuracy > 99%");
    rep
}

/// Fig 7: random rotation flattens coordinate-distance tails (Lemma 3).
///
/// Uses image-like data with sparse "object" spikes: real images differ
/// in localized regions (edges, objects), which is what makes their
/// coordinate-distance tails heavy and what the HD rotation flattens.
/// (On perfectly smooth fields the rotation has nothing to flatten.)
pub fn fig7(quick: bool, seed: u64) -> Report {
    let (n, d) = if quick { (40, 512) } else { (100, 4096) };
    let mut data = synthetic::image_like(n, d, seed);
    let mut rng = Rng::new(seed + 1);
    // sparse localized spikes, different coords per image
    for i in 0..n {
        for _ in 0..(d / 64).max(2) {
            let j = rng.below(d);
            data.row_mut(i)[j] += 1.0 + rng.f32() * 2.0;
        }
    }
    let (rotated, _rot) = Rotation::rotate_dataset(&data, &mut rng);
    let mut rep = Report::new(
        "Fig 7: coordinate-wise squared distances before/after HD rotation",
        &["pair", "max coord^2 before", "max after", "sigma bound shrink"]);
    for pair in 0..4 {
        let i = rng.below(n);
        let mut j = rng.below(n);
        while j == i {
            j = rng.below(n);
        }
        let max_sq = |ds: &DenseDataset, i: usize, j: usize| -> f64 {
            ds.row(i)
                .iter()
                .zip(ds.row(j))
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .fold(0.0, f64::max)
        };
        let before = max_sq(&data, i, j);
        let after = max_sq(&rotated, i, j);
        rep.row(vec![
            format!("({i},{j}) #{pair}"),
            fmt_f(before, 5),
            fmt_f(after, 5),
            fmt_gain(before / after.max(1e-12)),
        ]);
    }
    rep.note("Hoeffding sigma ~ max coord^2 / 2: the shrink column is the \
              sub-Gaussian-constant improvement of Lemma 3");
    rep
}

/// Proposition 1: sample complexity scales like (n+d)·log²(nd), not n·d.
pub fn prop1(quick: bool, seed: u64) -> Report {
    let configs: &[(usize, usize)] = if quick {
        &[(100, 256), (200, 256), (100, 1024), (200, 1024)]
    } else {
        &[(250, 512), (500, 512), (1000, 512),
          (250, 4096), (500, 4096), (1000, 4096)]
    };
    let mut rep = Report::new(
        "Proposition 1: measured pulls vs (n+d)log2(nd) under Gaussian means",
        &["n", "d", "M measured", "(n+d)log2(nd)", "ratio", "n*d"]);
    for &(n, d) in configs {
        let data = synthetic::gaussian_means(n + 1, d, 4.0, 1.0, seed);
        let mut engine = NativeEngine::default();
        let mut rng = Rng::new(seed + 7);
        let mut c = Counter::new();
        let _ = knn_point_dense(&data, 0, Metric::L2Sq, &bmo_params(1),
                                &mut engine, &mut rng, &mut c);
        let m = c.get();
        let pred = (n + d) as f64
            * ((n * d) as f64).ln() * ((n * d) as f64).ln();
        rep.row(vec![
            n.to_string(),
            d.to_string(),
            m.to_string(),
            fmt_f(pred, 0),
            fmt_f(m as f64 / pred, 3),
            (n as u64 * d as u64).to_string(),
        ]);
    }
    rep.note("ratio ~constant across (n,d) supports the (n+d)log2(nd) \
              scaling; contrast the n*d column (exact computation)");
    rep
}

/// Corollary 1: PAC complexity regimes under power-law gaps.
pub fn cor1(quick: bool, seed: u64) -> Report {
    let (n, d) = if quick { (200, 1024) } else { (500, 4096) };
    let alphas = [0.5, 1.0, 2.0, 3.0];
    // per-sample noise for these arms is sigma ~ theta*sqrt(2) ~ 2-4, so
    // the PAC rule bites for eps on the 0.25..1.5 scale; below that the
    // exact-eval cap takes over (the min(.., 2d) in Theorem 2)
    let epsilons = [1.5, 1.0, 0.5, 0.25];
    let mut rep = Report::new(
        "Corollary 1: PAC pulls vs epsilon under power-law gaps F(D)=D^a",
        &["alpha", "eps", "M measured", "eps-correct"]);
    for &alpha in &alphas {
        let data = synthetic::power_law_gaps(n, d, alpha, 1.0, seed);
        for &eps in &epsilons {
            let mut engine = NativeEngine::default();
            let mut rng = Rng::new(seed + 11);
            let mut c = Counter::new();
            let mut params = bmo_params(1);
            params.epsilon = eps;
            let res = knn_point_dense(&data, 0, Metric::L2Sq, &params,
                                      &mut engine, &mut rng, &mut c);
            let ok = pac::is_eps_correct(&data, 0, Metric::L2Sq, &res, 1,
                                         eps);
            rep.row(vec![
                fmt_f(alpha, 1),
                fmt_f(eps, 2),
                c.get().to_string(),
                ok.to_string(),
            ]);
        }
    }
    rep.note("paper: for a<2 cost grows as eps^(a-2); at a>2 cost is \
              ~independent of eps");
    rep
}

/// Theorem 1 sanity: error rate <= delta and M below the bound.
pub fn thm1(quick: bool, seed: u64) -> Report {
    let trials = if quick { 20 } else { 50 };
    let (n, d) = (100, 512);
    let delta = 0.05;
    let sigma_bound = 12.0; // generous known bound for gaussian_means data
    let mut errors = 0usize;
    let mut worst_ratio = 0f64;
    for t in 0..trials {
        let data = synthetic::gaussian_means(n, d, 4.0, 1.0,
                                             seed + t as u64);
        let truth = exact::knn_point(&data, 0, 1, Metric::L2Sq,
                                     &mut Counter::new());
        // theorem bound: M <= 2kd + sum_i min(8 s^2/D_i^2 log(2nd/dlt), 2d)
        let mut c0 = Counter::new();
        let thetas: Vec<f64> = (1..n)
            .map(|i| data.dist(0, i, Metric::L2Sq, &mut c0) / d as f64)
            .collect();
        let mut sorted = thetas.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let log_term = (2.0 * (n as f64 - 1.0) * d as f64 / delta).ln();
        let mut bound = 2.0 * d as f64;
        for th in &sorted[1..] {
            let gap = th - sorted[0];
            let by_gap = 8.0 * sigma_bound * sigma_bound / (gap * gap)
                * log_term;
            bound += by_gap.min(2.0 * d as f64);
        }
        let mut engine = NativeEngine::default();
        let mut rng = Rng::new(seed + 1000 + t as u64);
        let mut c = Counter::new();
        let mut params = bmo_params(1);
        params.delta = delta;
        params.sigma = SigmaMode::Fixed(sigma_bound);
        let res = knn_point_dense(&data, 0, Metric::L2Sq, &params,
                                  &mut engine, &mut rng, &mut c);
        if res.ids != truth.ids {
            errors += 1;
        }
        worst_ratio = worst_ratio.max(c.get() as f64 / bound);
    }
    let mut rep = Report::new(
        "Theorem 1: empirical error rate and sample-complexity bound",
        &["trials", "errors", "error rate", "delta",
          "worst M/bound ratio"]);
    rep.row(vec![
        trials.to_string(),
        errors.to_string(),
        fmt_f(errors as f64 / trials as f64, 3),
        fmt_f(delta, 3),
        fmt_f(worst_ratio, 3),
    ]);
    rep.note("error rate must be <= delta; M/bound <= 1 validates Eq. (6)");
    rep
}

/// Dispatch by name (CLI `bmonn bench <name>`). `shards` fans the BMO
/// runners' pull waves across a row-sharded pool (gain/accuracy numbers
/// are shard-count-independent; only wall clock changes).
pub fn run_figure(name: &str, quick: bool, seed: u64, shards: usize)
                  -> Result<Report, String> {
    Ok(match name {
        "fig3a" => fig3a(quick, seed, shards),
        "fig2" | "fig3b" => fig3b(quick, seed, shards),
        "fig4a" => fig4a(quick, seed, shards),
        "fig4b" => fig4b(quick, seed),
        "fig4c" => fig4c(quick, seed),
        "fig5" => fig5(quick, seed),
        "fig7" => fig7(quick, seed),
        "prop1" => prop1(quick, seed),
        "cor1" => cor1(quick, seed),
        "thm1" => thm1(quick, seed),
        _ => return Err(format!(
            "unknown figure '{name}' (try fig3a fig3b fig4a fig4b fig4c \
             fig5 fig7 prop1 cor1 thm1; `bench pull` is the sharded \
             pull-throughput baseline; fig6 is `cargo bench --bench \
             fig6_wallclock`)")),
    })
}

/// Helper for tests/benches: BMO units for one query on a workload.
pub fn bmo_units_one_query(n: usize, d: usize, k: usize, seed: u64) -> u64 {
    let data = synthetic::image_like(n, d, seed);
    let mut engine = NativeEngine::default();
    let mut rng = Rng::new(seed + 1);
    let mut c = Counter::new();
    let _ = knn_point_dense(&data, 0, Metric::L2Sq, &bmo_params(k),
                            &mut engine, &mut rng, &mut c);
    c.get()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3b_quick_bmo_beats_exact_and_wins_overall() {
        let rep = fig3b(true, 7, 1);
        // find BMO rows; gain should exceed 1x at the largest d
        let bmo_rows: Vec<&Vec<String>> = rep
            .rows
            .iter()
            .filter(|r| r[1] == "BMO-NN")
            .collect();
        assert!(!bmo_rows.is_empty());
        let last = bmo_rows.last().unwrap();
        let gain: f64 = last[2].trim_end_matches('x').parse().unwrap();
        assert!(gain > 2.0, "BMO gain at max d: {gain}");
        let acc: f64 = last[3].parse().unwrap();
        assert!(acc >= 0.9, "BMO accuracy {acc}");
    }

    #[test]
    fn fig4a_quick_shows_adaptivity_gap() {
        // 2 shards: free end-to-end coverage of the sharded engine (the
        // report is bitwise-independent of the shard count)
        let rep = fig4a(true, 11, 2);
        let bmo_acc: f64 = rep.rows[0][2].parse().unwrap();
        let uni_1x: f64 = rep.rows[1][2].parse().unwrap();
        assert!(bmo_acc > uni_1x,
                "BMO {bmo_acc} must beat uniform-at-1x {uni_1x}");
    }

    #[test]
    fn fig4b_quick_sparse_box_wins() {
        let rep = fig4b(true, 13);
        let sparse_gain: f64 =
            rep.rows[0][1].trim_end_matches('x').parse().unwrap();
        let dense_gain: f64 =
            rep.rows[1][1].trim_end_matches('x').parse().unwrap();
        assert!(sparse_gain > 1.0, "sparse box gain {sparse_gain}");
        assert!(sparse_gain > dense_gain,
                "sparse {sparse_gain} must beat dense {dense_gain}");
    }

    #[test]
    fn thm1_quick_respects_delta() {
        let rep = thm1(true, 17);
        let err_rate: f64 = rep.rows[0][2].parse().unwrap();
        let ratio: f64 = rep.rows[0][4].parse().unwrap();
        assert!(err_rate <= 0.05 + 1e-9, "error rate {err_rate}");
        assert!(ratio <= 1.0, "M exceeded Theorem 1 bound: ratio {ratio}");
    }

    #[test]
    fn run_figure_dispatch() {
        assert!(run_figure("nope", true, 0, 1).is_err());
        let r = run_figure("fig7", true, 0, 1).unwrap();
        assert!(!r.rows.is_empty());
    }
}
