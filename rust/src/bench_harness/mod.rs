//! Benchmark harness: workload generators, sweep drivers and table
//! printers that regenerate every table/figure of the paper's evaluation
//! (each runner in [`figures`] names the figure it reproduces).
//!
//! The same runners back the `bmonn bench <fig>` CLI and the
//! `cargo bench` targets; `quick=true` shrinks the workloads for CI.

pub mod figures;
pub mod pull_bench;

/// A printable experiment result (one table or figure series).
#[derive(Clone, Debug)]
pub struct Report {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str, headers: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width != header width");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        for n in &self.notes {
            out.push_str(&format!("> {n}\n"));
        }
        out
    }
}

/// Format a gain as "12.3x".
pub fn fmt_gain(g: f64) -> String {
    format!("{g:.1}x")
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Exact-set accuracy over queries (paper Appendix D-C1).
pub fn set_accuracy(got: &[Vec<u32>], want: &[Vec<u32>]) -> f64 {
    assert_eq!(got.len(), want.len());
    let mut ok = 0usize;
    for (g, w) in got.iter().zip(want) {
        let gs: std::collections::HashSet<_> = g.iter().collect();
        let ws: std::collections::HashSet<_> = w.iter().collect();
        ok += (gs == ws) as usize;
    }
    ok as f64 / got.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_aligned() {
        let mut r = Report::new("t", &["a", "longer"]);
        r.row(vec!["1".into(), "2".into()]);
        r.note("note");
        let s = r.render();
        assert!(s.contains("## t"));
        assert!(s.contains("longer"));
        assert!(s.contains("> note"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn report_rejects_ragged_rows() {
        let mut r = Report::new("t", &["a"]);
        r.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn set_accuracy_counts_exact_matches() {
        let got = vec![vec![1u32, 2], vec![3, 4]];
        let want = vec![vec![2u32, 1], vec![3, 5]];
        assert!((set_accuracy(&got, &want) - 0.5).abs() < 1e-12);
    }
}
