//! `bmonn bench pull` — the tracked pull-phase throughput baseline.
//!
//! Runs the 1k×256 batched multi-query workload (the server's execution
//! path: many bandits in lockstep, one coalesced `pull_batch` sweep per
//! round) plus a single-query latency sweep, on 1/2/4 local shards, **on
//! a 2-shard TCP-loopback remote ring** (in-process `shard-serve`
//! servers driven through `runtime::remote::RemoteEngine` — the tracked
//! distributed data point), **on a 2-shard failover rung** (a
//! replicated loopback ring whose primaries are all dead, so every wave
//! reaches the data through the replica-failover path — pinning that
//! failover steady-state costs the same as a healthy connection), **and
//! on a 2-shard multiplex rung** (two concurrent batch drivers sharing
//! one `runtime::remote::RingClient`, the query server's pattern: their
//! waves interleave on one connection per shard and the rung asserts
//! the per-connection in-flight high-water mark reached ≥ 2), **and on
//! a tcp-deadline rung** (a full query server over a loopback ring
//! under expired deadline budgets and an admission-control overload
//! burst — the rung asserts at least one query was shed, at least one
//! answered `deadline_exceeded`, and reports end-to-end queries/s plus
//! both counters in the JSON), **and on an http-front rung** (the
//! HTTP/1.1 front door over a loopback ring with the result cache on —
//! the rung asserts a repeat query hits the cache byte-identically to
//! its fresh compute, that an epoch bump invalidates the entry while
//! the recompute still answers the same bytes, and that a saturation
//! burst against `max_queue = 1` sheds with clean `429`s carrying
//! `Retry-After`), **and on a tcp-reshard rung** (the ring is doubled
//! live mid-sweep: staging servers take a fingerprint-verified dataset
//! transfer at the next placement epoch, an epoch-pinned client takes
//! over, and every answer on both sides of the flip must stay
//! bitwise-identical to the baseline), **and on a tcp-speculate rung**
//! (the identical workload twice over a loopback ring, cross-round
//! speculation off then on — round t+1's predicted pull wave overlaps
//! round t's retirement, answers must stay bitwise-identical both
//! ways, and the rung asserts at least one speculated pull was
//! confirmed while the caller-visible work counter stays identical),
//! and
//! emits the numbers as JSON for `BENCH_pull.json` so the perf
//! trajectory has data points that survive across PRs:
//!
//! * `pull_rows_per_s` — (row, query) jobs resolved per second inside
//!   `PullEngine::pull_batch` only (the parallelized hot phase);
//! * `wall_per_round_us` — mean wall clock of one coalesced round;
//! * `solo_p50_us` / `solo_p99_us` — per-query wall time of the
//!   single-query sweep (dominated by small waves, so it isolates the
//!   per-wave overhead each substrate adds: pool dispatch for local
//!   shards, a TCP round-trip for remote — that contrast is the point
//!   of tracking both).
//!
//! `--remote host:p,host:p` adds one more rung measured against a user
//! ring (its servers must load the bench dataset — see `--help`).
//!
//! Answers are asserted identical across every rung before any number
//! is reported: a throughput figure from a diverging engine is a bug,
//! not a data point. `smoke` shrinks the workload to a seconds-long CI
//! check.

use std::time::{Duration, Instant};

use crate::bench_harness::{fmt_f, Report};
use crate::config::EngineKind;
use crate::coordinator::arms::{PullEngine, PullRequest};
use crate::coordinator::bandit::BanditParams;
use crate::coordinator::knn::{knn_batch_points_dense, knn_point_dense};
use crate::data::dense::{DenseDataset, Metric};
use crate::data::synthetic;
use crate::metrics::{Counter, LatencyStats};
use crate::runtime::kernels::{self, KernelChoice};
use crate::runtime::{build_host_engine, remote};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Local shard counts the baseline sweeps; the acceptance tracking
/// compares the last entry against the first.
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

/// Shard count of the always-on in-process TCP-loopback remote rung.
const LOOPBACK_SHARDS: usize = 2;

/// Forwarding engine that clocks `pull_batch` calls — the coalesced pull
/// phase — without touching their results.
struct TimingEngine<E> {
    inner: E,
    pull_wall: Duration,
    pull_calls: u64,
    /// (row, query) jobs resolved across all pull_batch calls
    pull_jobs: u64,
}

impl<E: PullEngine> TimingEngine<E> {
    fn new(inner: E) -> TimingEngine<E> {
        TimingEngine {
            inner,
            pull_wall: Duration::ZERO,
            pull_calls: 0,
            pull_jobs: 0,
        }
    }
}

impl<E: PullEngine> PullEngine for TimingEngine<E> {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        self.inner.partial_sums(data, query, rows, coord_ids, metric,
                                out_sum, out_sq)
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        self.inner.exact_dists(data, query, rows, metric, out)
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let jobs: u64 = reqs.iter().map(|r| r.rows.len() as u64).sum();
        let t0 = Instant::now();
        self.inner.pull_batch(data, reqs, metric, out_sum, out_sq);
        self.pull_wall += t0.elapsed();
        self.pull_calls += 1;
        self.pull_jobs += jobs;
    }

    fn coverage(&mut self) -> Option<crate::coordinator::arms::Coverage> {
        self.inner.coverage()
    }

    fn quant_bias(&mut self, data: &DenseDataset, query: &[f32],
                  metric: Metric) -> f64 {
        self.inner.quant_bias(data, query, metric)
    }

    fn name(&self) -> &'static str {
        "timing"
    }
}

/// Per-rung measurement row.
struct ShardRun {
    shards: usize,
    /// "local" | "tcp-loopback" | "tcp-failover" | "tcp-multiplex" |
    /// "tcp-deadline" | "http-front" | "tcp-reshard" | "tcp-speculate"
    /// | "tcp-remote"
    transport: &'static str,
    rows_per_s: f64,
    wall_per_round_us: f64,
    rounds: u64,
    jobs: u64,
    batch_wall_ms: f64,
    solo_p50_us: f64,
    solo_p99_us: f64,
    /// tcp-multiplex only: high-water mark of concurrently in-flight
    /// sub-waves on one connection (asserted >= 2 — the pipelining
    /// witness)
    max_inflight: Option<u64>,
    /// tcp-deadline only: queries the server shed at admission during
    /// the rung's overload burst (asserted >= 1)
    shed: Option<u64>,
    /// tcp-deadline only: queries answered `deadline_exceeded`
    /// (asserted >= 1 — the rung sends expired-budget probes)
    deadline_exceeded: Option<u64>,
    /// http-front only: result-cache hits the rung's repeat queries
    /// produced (asserted >= 1, each byte-identical to the fresh
    /// compute)
    cache_hits: Option<u64>,
    /// tcp-reshard only: placement epoch the rung started on (the
    /// pre-flip loopback ring)
    epoch_from: Option<u64>,
    /// tcp-reshard only: placement epoch after the live reshard
    /// doubled the ring mid-sweep (always advances `epoch_from`)
    epoch_to: Option<u64>,
    /// tcp-speculate only: speculated per-query pulls whose prediction
    /// matched the real round and whose results were consumed in place
    /// of a fresh wave (asserted >= 1 — the overlap witness)
    spec_confirmed: Option<u64>,
}

/// Workload shape shared by every rung.
struct Workload<'a> {
    data: &'a DenseDataset,
    points: &'a [usize],
    solo_points: &'a [usize],
    params: &'a BanditParams,
    reps: usize,
    seed: u64,
}

/// Run the batched workload + solo sweep through one engine substrate
/// (`mk` builds it fresh for each of the two phases), asserting its
/// answers match every previous rung's.
fn measure_rung<F>(w: &Workload<'_>, shards: usize,
                   transport: &'static str, mk: F,
                   baseline_answers: &mut Option<Vec<Vec<u32>>>)
                   -> Result<ShardRun, String>
where
    F: Fn() -> Result<Box<dyn PullEngine + Send>, String>,
{
    // --- batched multi-query workload (the server's path), timed over
    // `reps` identical repetitions for a steadier pull clock -----------
    let mut engine = TimingEngine::new(mk()?);
    let mut batch_wall = Duration::ZERO;
    let mut answers: Vec<Vec<u32>> = Vec::new();
    for _ in 0..w.reps {
        let mut rng = Rng::new(w.seed + 1);
        let mut counter = Counter::new();
        let t0 = Instant::now();
        let results = knn_batch_points_dense(w.data, w.points,
                                             Metric::L2Sq, w.params,
                                             &mut engine, &mut rng,
                                             &mut counter);
        batch_wall += t0.elapsed();
        answers = results.into_iter().map(|r| r.ids).collect();
    }
    match baseline_answers {
        None => *baseline_answers = Some(answers),
        Some(base) => {
            if *base != answers {
                return Err(format!(
                    "answers diverged on the {transport} rung at {shards} \
                     shards — refusing to report throughput for a broken \
                     engine"));
            }
        }
    }
    let pull_secs = engine.pull_wall.as_secs_f64().max(1e-9);
    let rows_per_s = engine.pull_jobs as f64 / pull_secs;
    let wall_per_round_us = if engine.pull_calls > 0 {
        engine.pull_wall.as_secs_f64() * 1e6 / engine.pull_calls as f64
    } else {
        0.0
    };
    // --- single-query sweep (per-query latency) -----------------------
    let mut solo_engine = mk()?;
    let mut lat = LatencyStats::default();
    for (i, &q) in w.solo_points.iter().enumerate() {
        let mut qrng = Rng::new(w.seed + 100 + i as u64);
        let mut c = Counter::new();
        let t = Instant::now();
        let _ = knn_point_dense(w.data, q, Metric::L2Sq, w.params,
                                &mut solo_engine, &mut qrng, &mut c);
        lat.record(t.elapsed());
    }
    Ok(ShardRun {
        shards,
        transport,
        rows_per_s,
        wall_per_round_us,
        rounds: engine.pull_calls,
        jobs: engine.pull_jobs,
        batch_wall_ms: batch_wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: None,
        shed: None,
        deadline_exceeded: None,
        cache_hits: None,
        epoch_from: None,
        epoch_to: None,
        spec_confirmed: None,
    })
}

/// The always-on multiplex rung: one shared [`remote::RingClient`] over
/// a loopback ring, driven by (a) a deterministic two-waves-in-flight
/// pipelining check through the split submit/complete API, and (b) two
/// *concurrent* batch drivers on separate threads — the query server's
/// sharing pattern — whose answers must both match the baseline. The
/// client's per-connection in-flight high-water mark is recorded and
/// must reach ≥ 2 (waves demonstrably overlap on one connection).
fn measure_multiplex_rung(w: &Workload<'_>, endpoints: &[String],
                          baseline_answers: &mut Option<Vec<Vec<u32>>>)
                          -> Result<ShardRun, String> {
    use std::sync::Arc;
    let client = Arc::new(remote::RingClient::connect(endpoints)?);
    // (a) deterministic overlap: submit two waves through the pipelined
    // API before completing either — both are in flight on the same
    // per-shard connection — and pin their results against local compute
    {
        let mut eng = remote::RemoteEngine::from_client(client.clone());
        let mut local = crate::runtime::native::NativeEngine::default();
        let q0 = w.data.row_vec(0);
        let q1 = w.data.row_vec(1.min(w.data.n - 1));
        // a large first wave (repeated rows x 512 coords, millions of
        // coordinate ops) so its server-side compute comfortably
        // outlasts the submit of the second — the overlap below is then
        // reliable, not a race against a fast loopback server
        let rows: Vec<u32> = (0..w.data.n as u32)
            .cycle()
            .take(w.data.n * 8)
            .collect();
        let coords: Vec<u32> = (0..w.data.d as u32)
            .cycle()
            .take(512)
            .collect();
        let t0 = eng.submit_partial_sums(w.data, &q0, &rows, &coords,
                                         Metric::L2Sq);
        let t1 = eng.submit_partial_sums(w.data, &q1, &rows, &coords,
                                         Metric::L2Sq);
        let (mut s1, mut sq1) = (Vec::new(), Vec::new());
        eng.complete_sums(t1, &mut s1, &mut sq1);
        let (mut s0, mut sq0) = (Vec::new(), Vec::new());
        eng.complete_sums(t0, &mut s0, &mut sq0);
        let (mut l0, mut lq0) = (Vec::new(), Vec::new());
        let (mut l1, mut lq1) = (Vec::new(), Vec::new());
        local.partial_sums(w.data, &q0, &rows, &coords, Metric::L2Sq,
                           &mut l0, &mut lq0);
        local.partial_sums(w.data, &q1, &rows, &coords, Metric::L2Sq,
                           &mut l1, &mut lq1);
        if s0 != l0 || sq0 != lq0 || s1 != l1 || sq1 != lq1 {
            return Err("multiplex rung: pipelined submit/complete \
                        answers diverged from local compute"
                .into());
        }
    }
    // (b) two concurrent batch drivers sharing the client, timed
    let t0 = Instant::now();
    let (res_a, res_b) = std::thread::scope(|sc| {
        let spawn_driver = |_tag: usize| {
            let client = client.clone();
            sc.spawn(move || {
                let mut engine = TimingEngine::new(
                    remote::RemoteEngine::from_client(client));
                let mut answers: Vec<Vec<u32>> = Vec::new();
                for _ in 0..w.reps {
                    let mut rng = Rng::new(w.seed + 1);
                    let mut counter = Counter::new();
                    let results = knn_batch_points_dense(
                        w.data, w.points, Metric::L2Sq, w.params,
                        &mut engine, &mut rng, &mut counter);
                    answers =
                        results.into_iter().map(|r| r.ids).collect();
                }
                (answers, engine.pull_wall, engine.pull_calls,
                 engine.pull_jobs)
            })
        };
        let ha = spawn_driver(0);
        let hb = spawn_driver(1);
        let ra = ha.join().map_err(|_| {
            "multiplex driver A panicked mid-bench".to_string()
        })?;
        let rb = hb.join().map_err(|_| {
            "multiplex driver B panicked mid-bench".to_string()
        })?;
        Ok::<_, String>((ra, rb))
    })?;
    let region_wall = t0.elapsed();
    let (answers_a, wall_a, calls_a, jobs_a) = res_a;
    let (answers_b, wall_b, calls_b, jobs_b) = res_b;
    for (tag, answers) in [("A", &answers_a), ("B", &answers_b)] {
        match baseline_answers {
            None => *baseline_answers = Some(answers.clone()),
            Some(base) => {
                if base != answers {
                    return Err(format!(
                        "answers diverged on the tcp-multiplex rung \
                         (driver {tag}) — refusing to report throughput \
                         for a broken engine"));
                }
            }
        }
    }
    let max_inflight = client.max_inflight_per_conn();
    if max_inflight < 2 {
        return Err(format!(
            "multiplex rung: per-connection in-flight high-water mark is \
             {max_inflight} — waves never overlapped on one connection"));
    }
    // rows/s under the SAME definition as every other rung — jobs per
    // second of time spent inside pull_batch — so the tracked baseline
    // stays comparable across transports. With two concurrent drivers
    // that is the sum of each driver's own pull-phase rate (their pull
    // windows overlap in wall time); the concurrent region's wall
    // clock is reported separately as batch_wall_ms.
    let jobs = jobs_a + jobs_b;
    let rate_a = jobs_a as f64 / wall_a.as_secs_f64().max(1e-9);
    let rate_b = jobs_b as f64 / wall_b.as_secs_f64().max(1e-9);
    let pull_wall = wall_a + wall_b;
    let rounds = calls_a + calls_b;
    // solo latency through the shared client (unchanged path)
    let mut solo_engine = remote::RemoteEngine::from_client(client.clone());
    let mut lat = LatencyStats::default();
    for (i, &q) in w.solo_points.iter().enumerate() {
        let mut qrng = Rng::new(w.seed + 100 + i as u64);
        let mut c = Counter::new();
        let t = Instant::now();
        let _ = knn_point_dense(w.data, q, Metric::L2Sq, w.params,
                                &mut solo_engine, &mut qrng, &mut c);
        lat.record(t.elapsed());
    }
    Ok(ShardRun {
        shards: LOOPBACK_SHARDS,
        transport: "tcp-multiplex",
        rows_per_s: rate_a + rate_b,
        wall_per_round_us: if rounds > 0 {
            pull_wall.as_secs_f64() * 1e6 / rounds as f64
        } else {
            0.0
        },
        rounds,
        jobs,
        batch_wall_ms: region_wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: Some(max_inflight),
        shed: None,
        deadline_exceeded: None,
        cache_hits: None,
        epoch_from: None,
        epoch_to: None,
        spec_confirmed: None,
    })
}

/// The always-on deadline/admission rung: a full query [`Server`] (one
/// worker, wait-a-little batching, `max_queue = 1`, a generous 10 s
/// default budget) coordinating a loopback shard ring — the whole PR 7
/// robustness path under load:
///
/// 1. expired-budget probes (`deadline_ms: 1` against a 5 ms linger)
///    must come back as structured `deadline_exceeded` answers;
/// 2. a concurrent burst against the bounded queue must shed at least
///    one query with an `overload` answer;
/// 3. a sequential sweep with the default budget must answer every
///    query `ok` — that sweep is the rung's reported throughput.
///
/// Unlike the other rungs this one reports **queries resolved per
/// second end to end through the server** (not pull-phase rows/s): its
/// subject is the admission/deadline machinery wrapped around compute,
/// not the compute itself. Answer parity is not asserted here — worker
/// RNGs are seeded per worker, not per workload; parity is pinned by
/// the other rungs and the chaos suite.
fn measure_deadline_rung(w: &Workload<'_>) -> Result<ShardRun, String> {
    use crate::coordinator::server::{Client, Server, ServerConfig};
    let knn_req = |q: &[f32], k: usize, deadline_ms: Option<u64>| {
        let mut fields = vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(q)),
            ("k", Json::Num(k as f64)),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms as f64)));
        }
        Json::obj(fields)
    };
    let stats_req = Json::obj(vec![("op", Json::Str("stats".into()))]);
    let (_ring, endpoints) =
        remote::spawn_loopback_ring(w.data, LOOPBACK_SHARDS)?;
    let sc = ServerConfig {
        addr: "127.0.0.1:0".into(),
        metric: Metric::L2Sq,
        params: w.params.clone(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints,
        // the worker lingers 5 ms on every non-full batch: long enough
        // that a 1 ms probe budget reliably expires in-queue and that a
        // burst reliably finds the single queue slot occupied
        batch_wait_us: 5_000,
        deadline_ms: 10_000,
        max_queue: 1,
        ..ServerConfig::default()
    };
    let srv = Server::start(w.data.clone(), sc)
        .map_err(|e| format!("deadline rung server: {e}"))?;
    let addr = srv.addr;
    let mut cl = Client::connect(&addr).map_err(|e| e.to_string())?;
    let q0 = w.data.row_vec(0);
    // 1. expired budgets answer structurally, never hang
    for _ in 0..3 {
        let resp = cl
            .request(&knn_req(&q0, w.params.k, Some(1)))
            .map_err(|e| e.to_string())?;
        if resp.get("kind").and_then(|v| v.as_str())
            != Some("deadline_exceeded")
        {
            return Err(format!(
                "deadline rung: 1ms budget against a 5ms linger must \
                 expire, got {resp}"));
        }
    }
    // 2. concurrent bursts against max_queue=1 until a shed registers
    // (overwhelmingly round one; bounded so a broken admission path
    // fails the bench instead of spinning)
    let mut shed = 0u64;
    for _ in 0..50 {
        std::thread::scope(|sc| {
            for _ in 0..8 {
                sc.spawn(|| {
                    if let Ok(mut c) = Client::connect(&addr) {
                        for _ in 0..4 {
                            let _ = c.request(&knn_req(&q0, w.params.k,
                                                       None));
                        }
                    }
                });
            }
        });
        let stats =
            cl.request(&stats_req).map_err(|e| e.to_string())?;
        shed = stats
            .get("shed")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        if shed > 0 {
            break;
        }
    }
    if shed == 0 {
        return Err("deadline rung: 50 concurrent bursts against \
                    max_queue=1 never shed a query — admission control \
                    is not admitting-controlling".into());
    }
    // 3. throughput: sequential sweep under the generous default budget
    let mut lat = LatencyStats::default();
    let mut ok = 0u64;
    let t0 = Instant::now();
    for &p in w.solo_points {
        let q = w.data.row_vec(p);
        let t = Instant::now();
        let resp = cl
            .request(&knn_req(&q, w.params.k, None))
            .map_err(|e| e.to_string())?;
        lat.record(t.elapsed());
        if resp.get("ok") == Some(&Json::Bool(true)) {
            ok += 1;
        } else {
            return Err(format!(
                "deadline rung: sequential query under a 10s budget \
                 failed: {resp}"));
        }
    }
    let wall = t0.elapsed();
    let stats = cl.request(&stats_req).map_err(|e| e.to_string())?;
    let deadline_exceeded = stats
        .get("deadline_exceeded")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64;
    if deadline_exceeded == 0 {
        return Err("deadline rung: stats lost the deadline_exceeded \
                    count the probes produced".into());
    }
    Ok(ShardRun {
        shards: LOOPBACK_SHARDS,
        transport: "tcp-deadline",
        rows_per_s: ok as f64 / wall.as_secs_f64().max(1e-9),
        wall_per_round_us: wall.as_secs_f64() * 1e6 / ok.max(1) as f64,
        rounds: ok,
        jobs: ok,
        batch_wall_ms: wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: None,
        shed: Some(shed),
        deadline_exceeded: Some(deadline_exceeded),
        cache_hits: None,
        epoch_from: None,
        epoch_to: None,
        spec_confirmed: None,
    })
}

/// The always-on http-front rung: the full HTTP/1.1 front door over a
/// loopback ring, with the result cache on.
///
/// Sequence: (1) a repeat query must hit the cache **byte-identically**
/// to its fresh compute, and a `POST /admin/epoch-bump` must invalidate
/// the entry while the recompute still answers the same bytes (seeded
/// serving compute); (2) a saturation burst against `max_queue = 1`
/// must produce clean `429`s carrying `Retry-After`; (3) a sequential
/// sweep reports end-to-end HTTP queries/s with p50/p99. Like the
/// deadline rung, throughput here includes HTTP framing, validation,
/// queueing and batching — not just the pull phase.
fn measure_http_front_rung(w: &Workload<'_>) -> Result<ShardRun, String> {
    use crate::coordinator::http::http_request;
    use crate::coordinator::server::{Server, ServerConfig};
    use std::sync::atomic::{AtomicU64, Ordering};
    let knn_body = |q: &[f32], k: usize| {
        Json::obj(vec![
            ("query", Json::f32_array(q)),
            ("k", Json::Num(k as f64)),
        ])
        .to_string()
    };
    let (_ring, endpoints) =
        remote::spawn_loopback_ring(w.data, LOOPBACK_SHARDS)?;
    let sc = ServerConfig {
        addr: "127.0.0.1:0".into(),
        metric: Metric::L2Sq,
        params: w.params.clone(),
        n_workers: 1,
        batch_size: 4,
        remote: endpoints,
        // same shape as the deadline rung: the 5 ms linger keeps the
        // single queue slot reliably occupied during the burst
        batch_wait_us: 5_000,
        deadline_ms: 10_000,
        max_queue: 1,
        http_port: Some(0),
        cache_entries: 64,
        ..ServerConfig::default()
    };
    let srv = Server::start(w.data.clone(), sc)
        .map_err(|e| format!("http-front rung server: {e}"))?;
    let http = srv
        .http_addr
        .ok_or("http-front rung: server did not bind an HTTP port")?;
    // 1. cache correctness end to end: miss, byte-identical hit,
    // epoch-flip invalidation, byte-identical recompute
    let q0 = w.data.row_vec(0);
    let body0 = knn_body(&q0, w.params.k);
    let (s1, _, fresh) = http_request(&http, "POST", "/knn",
                                      Some(&body0))
        .map_err(|e| e.to_string())?;
    if s1 != 200 {
        return Err(format!(
            "http-front rung: fresh query answered {s1}: {fresh}"));
    }
    let (s2, _, hit) = http_request(&http, "POST", "/knn", Some(&body0))
        .map_err(|e| e.to_string())?;
    if s2 != 200 || hit != fresh {
        return Err(format!(
            "http-front rung: cache hit must be byte-identical to the \
             fresh compute (status {s2})"));
    }
    let (s3, _, _) =
        http_request(&http, "POST", "/admin/epoch-bump", Some(""))
            .map_err(|e| e.to_string())?;
    if s3 != 200 {
        return Err(format!("http-front rung: epoch bump answered {s3}"));
    }
    let (s4, _, recomputed) =
        http_request(&http, "POST", "/knn", Some(&body0))
            .map_err(|e| e.to_string())?;
    if s4 != 200 || recomputed != fresh {
        return Err(format!(
            "http-front rung: the post-epoch-flip recompute must answer \
             the same bytes as before the flip (status {s4}) — seeded \
             serving compute is not deterministic"));
    }
    let (sm, _, metrics) = http_request(&http, "GET", "/metrics", None)
        .map_err(|e| e.to_string())?;
    if sm != 200 {
        return Err(format!("http-front rung: /metrics answered {sm}"));
    }
    let metrics = Json::parse(metrics.trim())
        .map_err(|e| format!("http-front rung: bad /metrics json: {e}"))?;
    let cache_hits = metrics
        .get("cache_hits")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0) as u64;
    if cache_hits == 0 {
        return Err("http-front rung: /metrics lost the cache hit the \
                    repeat query produced".into());
    }
    // 2. saturation burst against max_queue=1 until clean 429s register
    // (random queries so the cache cannot absorb the burst; bounded so
    // a broken admission path fails the bench instead of spinning)
    let sheds = AtomicU64::new(0);
    let bad_retry_after = AtomicU64::new(0);
    let mut rng = Rng::new(w.seed + 900);
    'burst: for _ in 0..50 {
        let bodies: Vec<String> = (0..32)
            .map(|_| {
                let q: Vec<f32> = (0..w.data.d)
                    .map(|_| rng.gaussian() as f32)
                    .collect();
                knn_body(&q, w.params.k)
            })
            .collect();
        std::thread::scope(|scope| {
            for chunk in bodies.chunks(4) {
                let sheds = &sheds;
                let bad_retry_after = &bad_retry_after;
                scope.spawn(move || {
                    for body in chunk {
                        let Ok((status, headers, _)) = http_request(
                            &http, "POST", "/knn", Some(body))
                        else {
                            continue;
                        };
                        if status == 429 {
                            sheds.fetch_add(1, Ordering::Relaxed);
                            let ok_header = headers.iter().any(
                                |(n, v)| n == "retry-after"
                                    && v.parse::<u64>()
                                        .is_ok_and(|s| s >= 1));
                            if !ok_header {
                                bad_retry_after
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        if sheds.load(Ordering::Relaxed) > 0 {
            break 'burst;
        }
    }
    let shed = sheds.load(Ordering::Relaxed);
    if shed == 0 {
        return Err("http-front rung: 50 concurrent bursts against \
                    max_queue=1 never answered a 429".into());
    }
    if bad_retry_after.load(Ordering::Relaxed) > 0 {
        return Err("http-front rung: a 429 arrived without a usable \
                    Retry-After header".into());
    }
    // 3. throughput: sequential sweep; every query must answer 200
    let mut lat = LatencyStats::default();
    let mut ok = 0u64;
    let t0 = Instant::now();
    for &p in w.solo_points {
        let body = knn_body(&w.data.row_vec(p), w.params.k);
        let t = Instant::now();
        let (status, _, resp) =
            http_request(&http, "POST", "/knn", Some(&body))
                .map_err(|e| e.to_string())?;
        lat.record(t.elapsed());
        if status != 200 {
            return Err(format!(
                "http-front rung: sequential query answered {status}: \
                 {resp}"));
        }
        ok += 1;
    }
    let wall = t0.elapsed();
    Ok(ShardRun {
        shards: LOOPBACK_SHARDS,
        transport: "http-front",
        rows_per_s: ok as f64 / wall.as_secs_f64().max(1e-9),
        wall_per_round_us: wall.as_secs_f64() * 1e6 / ok.max(1) as f64,
        rounds: ok,
        jobs: ok,
        batch_wall_ms: wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: None,
        shed: Some(shed),
        deadline_exceeded: None,
        cache_hits: Some(cache_hits),
        epoch_from: None,
        epoch_to: None,
        spec_confirmed: None,
    })
}

/// The always-on reshard rung: the identical workload against a
/// 2-shard loopback ring at placement epoch 0, except that halfway
/// through the reps the ring is **doubled live**: four staging servers
/// come up empty, [`remote::reshard_to`] streams each its row range as
/// a 4-shard placement at epoch 1 (fingerprint-verified at commit), a
/// fresh client connects pinned to `expect_epoch = 1`, the old servers
/// are dropped, and the remaining reps run on the new ring. Every
/// answer — before and after the flip — must be bitwise identical to
/// the baseline, which is the whole point of an *elastic* ring: a
/// topology change is invisible to query results. The rung records the
/// epochs it flipped between for `BENCH_pull.json`.
fn measure_reshard_rung(w: &Workload<'_>,
                        baseline_answers: &mut Option<Vec<Vec<u32>>>)
                        -> Result<ShardRun, String> {
    use crate::runtime::placement::PlacementMap;
    use std::sync::Arc;
    let (old_ring, endpoints) =
        remote::spawn_loopback_ring(w.data, LOOPBACK_SHARDS)?;
    let mut old_ring = Some(old_ring);
    let mut engine = TimingEngine::new(
        remote::RemoteEngine::connect(&endpoints)
            .map(|e| Box::new(e) as Box<dyn PullEngine + Send>)?);
    let (epoch_from, epoch_to) = (0u64, 1u64);
    let new_shards = LOOPBACK_SHARDS * 2;
    let mut staged: Vec<remote::ShardServer> = Vec::new();
    let mut batch_wall = Duration::ZERO;
    let flip_at = (w.reps / 2).max(1);
    for rep in 0..w.reps {
        if rep == flip_at {
            // double the ring live: empty staging servers take a
            // fingerprint-verified transfer of the 4-shard placement
            for i in 0..new_shards {
                staged.push(remote::ShardServer::start_staging(
                    "127.0.0.1:0", KernelChoice::Auto, None)
                    .map_err(|e| format!(
                        "reshard rung: staging server {i}: {e}"))?);
            }
            let specs: Vec<String> =
                staged.iter().map(|s| s.endpoint()).collect();
            let map = PlacementMap::parse(&specs)
                .map_err(|e| format!("reshard rung: {e}"))?;
            remote::reshard_to(w.data, &map, epoch_to, None)
                .map_err(|e| format!("reshard rung: transfer: {e}"))?;
            let client = Arc::new(remote::RingClient::connect_opts(
                &map,
                remote::RemoteOptions {
                    expect_epoch: Some(epoch_to),
                    ..remote::RemoteOptions::default()
                })?);
            if client.epoch() != epoch_to {
                return Err(format!(
                    "reshard rung: new ring reports epoch {} after the \
                     flip to {epoch_to}", client.epoch()));
            }
            engine.inner =
                Box::new(remote::RemoteEngine::from_client(client));
            // drop the old placement entirely: every remaining answer
            // can only come from the resharded ring
            drop(old_ring.take());
        }
        let mut rng = Rng::new(w.seed + 1);
        let mut counter = Counter::new();
        let t0 = Instant::now();
        let results = knn_batch_points_dense(w.data, w.points,
                                             Metric::L2Sq, w.params,
                                             &mut engine, &mut rng,
                                             &mut counter);
        batch_wall += t0.elapsed();
        let answers: Vec<Vec<u32>> =
            results.into_iter().map(|r| r.ids).collect();
        match baseline_answers {
            None => *baseline_answers = Some(answers),
            Some(base) => {
                if *base != answers {
                    let side =
                        if rep < flip_at { "before" } else { "after" };
                    return Err(format!(
                        "answers diverged on the tcp-reshard rung \
                         {side} the epoch {epoch_from}→{epoch_to} flip \
                         — refusing to report throughput for a broken \
                         engine"));
                }
            }
        }
    }
    let pull_secs = engine.pull_wall.as_secs_f64().max(1e-9);
    let rows_per_s = engine.pull_jobs as f64 / pull_secs;
    let wall_per_round_us = if engine.pull_calls > 0 {
        engine.pull_wall.as_secs_f64() * 1e6 / engine.pull_calls as f64
    } else {
        0.0
    };
    // solo sweep through the post-flip ring (the new steady state)
    let mut lat = LatencyStats::default();
    for (i, &q) in w.solo_points.iter().enumerate() {
        let mut qrng = Rng::new(w.seed + 100 + i as u64);
        let mut c = Counter::new();
        let t = Instant::now();
        let _ = knn_point_dense(w.data, q, Metric::L2Sq, w.params,
                                &mut engine.inner, &mut qrng, &mut c);
        lat.record(t.elapsed());
    }
    Ok(ShardRun {
        shards: new_shards,
        transport: "tcp-reshard",
        rows_per_s,
        wall_per_round_us,
        rounds: engine.pull_calls,
        jobs: engine.pull_jobs,
        batch_wall_ms: batch_wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: None,
        shed: None,
        deadline_exceeded: None,
        cache_hits: None,
        epoch_from: Some(epoch_from),
        epoch_to: Some(epoch_to),
        spec_confirmed: None,
    })
}

/// One batched pass of the speculate rung's workload: the shared
/// workload points under the rung's scaled pull policy, one rep,
/// returning (answer ids, speculation counters, caller-visible
/// `Counter` charge).
fn speculate_pass<E: PullEngine>(
    w: &Workload<'_>,
    params: &BanditParams,
    engine: &mut E,
    speculate: bool,
) -> (Vec<Vec<u32>>, crate::coordinator::knn::SpecStats, u64) {
    use crate::coordinator::knn::{knn_batch_points_dense_opts,
                                  BatchOptions};
    let mut rng = Rng::new(w.seed + 1);
    let mut counter = Counter::new();
    let opts = BatchOptions { deadline: None, speculate };
    let (results, spec) = knn_batch_points_dense_opts(
        w.data, w.points, Metric::L2Sq, params, engine, &mut rng,
        &mut counter, opts);
    (results.into_iter().map(|r| r.ids).collect(), spec, counter.get())
}

/// The always-on speculate rung: the same batched workload over a
/// fresh loopback ring, run twice through the batch driver's options
/// API — speculation off, then on — on a bare [`remote::RemoteEngine`]
/// (no timing wrapper: the wrapper forwards only the blocking engine
/// subset, which would mask `PullEngine::pipelined` and render
/// speculation inert). Speculation only engages while arms still have
/// several uniform `round_pulls`-sized waves of cap headroom, so the
/// rung scales its own pull policy to the dataset (`round_pulls =
/// d/8`) instead of inheriting the baseline's — the smoke shape's
/// `round_pulls = d` caps every arm straight after the init wave —
/// and therefore pins its answers against a local single-shard
/// reference computed under the identical policy rather than the
/// shared baseline.
///
/// The rung asserts the off and on passes both answer
/// bitwise-identically to the local reference, that the off pass
/// reports all-zero speculation counters, that the on pass confirmed
/// at least one speculated pull (the overlap witness serialized as
/// `spec_confirmed`), that `speculated == confirmed + discarded`, and
/// that the caller-visible `Counter` charge is identical on vs off —
/// speculative work never bills the caller.
///
/// Unlike the pull-phase rungs this one reports **end-to-end batch
/// numbers**: `rows_per_s` is Counter work units per second of batch
/// wall with speculation on, `wall_per_round_us` is mean batch wall
/// per rep, and `rounds`/`jobs` are reps / Counter units — its subject
/// is whole-batch wall clock moved by overlapping round t+1's wave
/// with round t's retirement, not the pull kernels underneath.
fn measure_speculate_rung(w: &Workload<'_>) -> Result<ShardRun, String> {
    use crate::coordinator::knn::SpecStats;
    let mut params = w.params.clone();
    params.policy.round_pulls = (w.data.d as u64 / 8).max(1);
    let (_ring, endpoints) =
        remote::spawn_loopback_ring(w.data, LOOPBACK_SHARDS)?;
    // local single-shard reference under the rung's own pull policy
    let mut local = crate::runtime::native::NativeEngine::default();
    let (ref_answers, ref_spec, _ref_jobs) =
        speculate_pass(w, &params, &mut local, false);
    if ref_spec != SpecStats::default() {
        return Err(format!(
            "speculate rung: local blocking reference reported nonzero \
             speculation counters {ref_spec:?}"));
    }
    let pass = |speculate: bool| -> Result<
        (Vec<Vec<u32>>, Duration, SpecStats, u64), String> {
        let mut engine = remote::RemoteEngine::connect(&endpoints)?;
        let mut wall = Duration::ZERO;
        let mut answers: Vec<Vec<u32>> = Vec::new();
        let mut spec = SpecStats::default();
        let mut jobs = 0u64;
        for _ in 0..w.reps {
            let t0 = Instant::now();
            let (a, s, j) =
                speculate_pass(w, &params, &mut engine, speculate);
            wall += t0.elapsed();
            spec.merge(&s);
            jobs += j;
            answers = a;
        }
        Ok((answers, wall, spec, jobs))
    };
    let (off_answers, _off_wall, off_spec, off_jobs) = pass(false)?;
    let (on_answers, on_wall, on_spec, on_jobs) = pass(true)?;
    if off_answers != ref_answers {
        return Err("answers diverged on the tcp-speculate rung \
                    (speculation off vs local reference) — refusing to \
                    report throughput for a broken engine"
            .into());
    }
    if on_answers != ref_answers {
        return Err("answers diverged between speculation on and the \
                    local reference on the tcp-speculate rung — \
                    speculation must be bitwise-invisible"
            .into());
    }
    if off_spec != SpecStats::default() {
        return Err(format!(
            "speculate rung: speculation-off pass reported nonzero \
             speculation counters {off_spec:?}"));
    }
    if on_spec.confirmed == 0 {
        return Err(format!(
            "speculate rung: no speculated pull was ever confirmed \
             ({on_spec:?}) — the overlap path never engaged"));
    }
    if on_spec.speculated != on_spec.confirmed + on_spec.discarded {
        return Err(format!(
            "speculate rung: counter invariant broke: {on_spec:?}"));
    }
    if on_jobs != off_jobs {
        return Err(format!(
            "speculate rung: caller-visible Counter charge differs on \
             ({on_jobs}) vs off ({off_jobs}) — speculative waves must \
             never bill the caller"));
    }
    // solo latency through the same ring (standard sweep; speculation
    // is a batch-driver feature, solo queries take the ordinary path)
    let mut solo_engine = remote::RemoteEngine::connect(&endpoints)?;
    let mut lat = LatencyStats::default();
    for (i, &q) in w.solo_points.iter().enumerate() {
        let mut qrng = Rng::new(w.seed + 100 + i as u64);
        let mut c = Counter::new();
        let t = Instant::now();
        let _ = knn_point_dense(w.data, q, Metric::L2Sq, w.params,
                                &mut solo_engine, &mut qrng, &mut c);
        lat.record(t.elapsed());
    }
    Ok(ShardRun {
        shards: LOOPBACK_SHARDS,
        transport: "tcp-speculate",
        rows_per_s: on_jobs as f64 / on_wall.as_secs_f64().max(1e-9),
        wall_per_round_us: on_wall.as_secs_f64() * 1e6
            / (w.reps as f64).max(1.0),
        rounds: w.reps as u64,
        jobs: on_jobs,
        batch_wall_ms: on_wall.as_secs_f64() * 1e3,
        solo_p50_us: lat.percentile(50.0).as_micros() as f64,
        solo_p99_us: lat.percentile(99.0).as_micros() as f64,
        max_inflight: None,
        shed: None,
        deadline_exceeded: None,
        cache_hits: None,
        epoch_from: None,
        epoch_to: None,
        spec_confirmed: Some(on_spec.confirmed),
    })
}

/// One row of the single-core kernel-tier rung: a forced kernel tier
/// and its raw `partial_sums` throughput on one core (no sharding, no
/// bandit loop — this isolates the dispatched row kernels themselves).
struct KernelRun {
    tier: &'static str,
    rows_per_s: f64,
    speedup_vs_scalar: f64,
}

/// Measure raw single-core `partial_sums` throughput per kernel tier:
/// scalar always (the anchor the speedup column divides by), plus the
/// auto-dispatched tier of this host when it differs. Cross-tier
/// answers are checked against scalar at 1e-5 relative tolerance — the
/// bitwise contract holds per tier, not across tiers (docs/CONFIG.md),
/// but a tier drifting past the parity-test tolerance is a broken
/// kernel, not a data point.
fn measure_kernel_tiers(data: &DenseDataset, seed: u64, waves: usize)
                        -> Result<Vec<KernelRun>, String> {
    let mut rng = Rng::new(seed + 500);
    let q: Vec<f32> =
        (0..data.d).map(|_| rng.gaussian() as f32).collect();
    let rows: Vec<u32> = (0..data.n as u32).collect();
    let coords: Vec<u32> =
        (0..64).map(|_| rng.below(data.d) as u32).collect();
    let mut choices = vec![KernelChoice::Scalar];
    if kernels::detect() != kernels::KernelTier::Scalar {
        choices.push(KernelChoice::Auto);
    }
    let mut runs: Vec<KernelRun> = Vec::new();
    let mut scalar_sums: Vec<f64> = Vec::new();
    for choice in choices {
        let mut engine =
            crate::runtime::native::NativeEngine::with_options(choice,
                                                               false)?;
        let tier = engine.kernel_tier().as_str();
        let (mut sums, mut sqs) = (Vec::new(), Vec::new());
        // warm-up wave: page the dataset in before the clock starts
        engine.partial_sums(data, &q, &rows, &coords, Metric::L2Sq,
                            &mut sums, &mut sqs);
        let t0 = Instant::now();
        for _ in 0..waves {
            engine.partial_sums(data, &q, &rows, &coords, Metric::L2Sq,
                                &mut sums, &mut sqs);
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        if runs.is_empty() {
            scalar_sums = sums.clone();
        } else {
            for (a, b) in scalar_sums.iter().zip(&sums) {
                let tol = 1e-5 * a.abs().max(b.abs()).max(1.0);
                if (a - b).abs() > tol {
                    return Err(format!(
                        "kernel rung: {tier} diverged from scalar \
                         beyond tolerance ({a} vs {b})"));
                }
            }
        }
        let rows_per_s = (rows.len() * waves) as f64 / secs;
        let speedup = match runs.first() {
            Some(s) => rows_per_s / s.rows_per_s.max(1e-9),
            None => 1.0,
        };
        runs.push(KernelRun {
            tier,
            rows_per_s,
            speedup_vs_scalar: speedup,
        });
    }
    Ok(runs)
}

fn run_json(r: &ShardRun) -> Json {
    let mut fields = vec![
        ("shards", Json::Num(r.shards as f64)),
        ("transport", Json::Str(r.transport.to_string())),
        ("pull_rows_per_s", Json::Num(r.rows_per_s)),
        ("wall_per_round_us", Json::Num(r.wall_per_round_us)),
        ("pull_rounds", Json::Num(r.rounds as f64)),
        ("pull_jobs", Json::Num(r.jobs as f64)),
        ("batch_wall_ms", Json::Num(r.batch_wall_ms)),
        ("solo_p50_us", Json::Num(r.solo_p50_us)),
        ("solo_p99_us", Json::Num(r.solo_p99_us)),
    ];
    if let Some(mi) = r.max_inflight {
        fields.push(("max_inflight", Json::Num(mi as f64)));
    }
    if let Some(s) = r.shed {
        fields.push(("shed", Json::Num(s as f64)));
    }
    if let Some(de) = r.deadline_exceeded {
        fields.push(("deadline_exceeded", Json::Num(de as f64)));
    }
    if let Some(ch) = r.cache_hits {
        fields.push(("cache_hits", Json::Num(ch as f64)));
    }
    if let Some(e) = r.epoch_from {
        fields.push(("epoch_from", Json::Num(e as f64)));
    }
    if let Some(e) = r.epoch_to {
        fields.push(("epoch_to", Json::Num(e as f64)));
    }
    if let Some(sc) = r.spec_confirmed {
        fields.push(("spec_confirmed", Json::Num(sc as f64)));
    }
    Json::obj(fields)
}

/// Run the baseline; returns the printable table plus the JSON document
/// written to `BENCH_pull.json`. `extra_remote` (from `--remote`) adds a
/// rung against a user-provided shard-serve ring.
pub fn run_pull_bench(smoke: bool, seed: u64, extra_remote: &[String])
                      -> Result<(Report, Json), String> {
    let (n, d, batch, solo_q, reps) =
        if smoke { (256, 64, 16, 4, 2) } else { (1000, 256, 64, 32, 5) };
    let data = synthetic::image_like(n, d, seed);
    let points: Vec<usize> = (0..batch).map(|i| i % n).collect();
    let solo_points: Vec<usize> =
        (0..solo_q).map(|i| (i * 7) % n).collect();
    // round_pulls below MAX_PULLS-after-init so the run issues several
    // coalesced uniform waves per query instead of going straight from
    // the init wave to capped/ragged pulls — this is the phase the
    // baseline exists to track
    let mut params = BanditParams { k: 5, ..Default::default() };
    params.policy.round_pulls = 64;
    let w = Workload {
        data: &data,
        points: &points,
        solo_points: &solo_points,
        params: &params,
        reps,
        seed,
    };
    let mut baseline_answers: Option<Vec<Vec<u32>>> = None;
    let mut local_runs: Vec<ShardRun> = Vec::new();
    for &shards in &SHARD_COUNTS {
        local_runs.push(measure_rung(
            &w,
            shards,
            "local",
            || build_host_engine(EngineKind::Native, shards, &[], false,
                                 KernelChoice::Auto, false, false, None),
            &mut baseline_answers,
        )?);
    }
    // --- distributed rungs: the identical workload through RemoteEngine
    // over an in-process loopback ring (answers must stay identical —
    // the wire moves float bits verbatim), plus a user ring if given ---
    let mut remote_runs: Vec<ShardRun> = Vec::new();
    {
        let (_ring, endpoints) =
            remote::spawn_loopback_ring(&data, LOOPBACK_SHARDS)?;
        remote_runs.push(measure_rung(
            &w,
            LOOPBACK_SHARDS,
            "tcp-loopback",
            || {
                remote::RemoteEngine::connect(&endpoints)
                    .map(|e| Box::new(e) as Box<dyn PullEngine + Send>)
            },
            &mut baseline_answers,
        )?);
        // _ring stops (and its servers drop) at the end of this scope
    }
    {
        // failover rung: a replicated ring whose primaries are all dead
        // before the first connect, so every wave reaches the data via
        // the replica-failover path — same workload, same answers
        let (primaries, p_eps) =
            remote::spawn_loopback_ring(&data, LOOPBACK_SHARDS)?;
        let (_replicas, r_eps) =
            remote::spawn_loopback_ring(&data, LOOPBACK_SHARDS)?;
        let specs: Vec<String> = p_eps
            .iter()
            .zip(&r_eps)
            .map(|(p, r)| format!("{p}|{r}"))
            .collect();
        drop(primaries); // kill every primary: failover must carry it
        remote_runs.push(measure_rung(
            &w,
            LOOPBACK_SHARDS,
            "tcp-failover",
            || {
                remote::RemoteEngine::connect(&specs)
                    .map(|e| Box::new(e) as Box<dyn PullEngine + Send>)
            },
            &mut baseline_answers,
        )?);
    }
    {
        // multiplex rung: two concurrent batch drivers share one
        // RingClient over a fresh loopback ring — overlapping waves on
        // one connection per shard, answers asserted identical to local
        let (_ring, endpoints) =
            remote::spawn_loopback_ring(&data, LOOPBACK_SHARDS)?;
        remote_runs.push(measure_multiplex_rung(&w, &endpoints,
                                                &mut baseline_answers)?);
    }
    // deadline/admission rung: a full query server over a loopback ring
    // under expired budgets and an overload burst (spawns its own ring)
    remote_runs.push(measure_deadline_rung(&w)?);
    // http-front rung: the HTTP/1.1 front door + result cache over a
    // loopback ring — byte-identical cache hits across an epoch flip,
    // clean 429s under saturation, end-to-end HTTP queries/s
    remote_runs.push(measure_http_front_rung(&w)?);
    // reshard rung: the ring doubles live mid-sweep — staging servers
    // take a fingerprint-verified transfer at the next placement
    // epoch, an epoch-pinned client takes over, and answers stay
    // bitwise-identical on both sides of the flip
    remote_runs.push(measure_reshard_rung(&w, &mut baseline_answers)?);
    // speculate rung: the same workload points twice over a fresh
    // loopback ring — speculation off then on, under the rung's own
    // d-scaled pull policy — answers bitwise-identical to a local
    // reference both ways, at least one speculated pull confirmed, and
    // the caller's Counter charged identically on vs off (speculative
    // work is never billed)
    remote_runs.push(measure_speculate_rung(&w)?);
    if !extra_remote.is_empty() {
        remote_runs.push(measure_rung(
            &w,
            extra_remote.len(),
            "tcp-remote",
            || {
                remote::RemoteEngine::connect(extra_remote)
                    .map(|e| Box::new(e) as Box<dyn PullEngine + Send>)
            },
            &mut baseline_answers,
        )?);
    }
    // --- single-core kernel-tier rung: raw partial_sums throughput per
    // dispatched kernel (scalar anchor + this host's auto tier) --------
    let kernel_waves = if smoke { 20 } else { 200 };
    let kernel_runs = measure_kernel_tiers(&data, seed, kernel_waves)?;
    let dispatched = kernel_runs.last().unwrap().tier;
    let speedup = local_runs.last().unwrap().rows_per_s
        / local_runs.first().unwrap().rows_per_s.max(1e-9);
    let mut rep = Report::new(
        "bench pull: sharded pull-phase throughput baseline \
         (BENCH_pull.json)",
        &["shards", "transport", "pull rows/s", "wall/round us", "rounds",
          "batch wall ms", "solo p50 us", "solo p99 us"]);
    for r in local_runs.iter().chain(&remote_runs) {
        rep.row(vec![
            r.shards.to_string(),
            r.transport.to_string(),
            format!("{:.0}", r.rows_per_s),
            fmt_f(r.wall_per_round_us, 1),
            r.rounds.to_string(),
            fmt_f(r.batch_wall_ms, 1),
            fmt_f(r.solo_p50_us, 0),
            fmt_f(r.solo_p99_us, 0),
        ]);
    }
    let multiplex_hwm = remote_runs
        .iter()
        .find_map(|r| r.max_inflight)
        .unwrap_or(0);
    let (rung_shed, rung_exceeded) = remote_runs
        .iter()
        .find_map(|r| r.shed.zip(r.deadline_exceeded))
        .unwrap_or((0, 0));
    let (http_shed, http_hits) = remote_runs
        .iter()
        .find(|r| r.transport == "http-front")
        .and_then(|r| r.shed.zip(r.cache_hits))
        .unwrap_or((0, 0));
    let (re_from, re_to) = remote_runs
        .iter()
        .find(|r| r.transport == "tcp-reshard")
        .and_then(|r| r.epoch_from.zip(r.epoch_to))
        .unwrap_or((0, 0));
    let spec_confirmed = remote_runs
        .iter()
        .find(|r| r.transport == "tcp-speculate")
        .and_then(|r| r.spec_confirmed)
        .unwrap_or(0);
    rep.note(&format!(
        "workload: n={n} d={d} (shard-serve --synthetic \
         image:{n}:{d}:{seed}), {batch} batched queries x{reps} reps + \
         {solo_q} solo queries; pull-phase speedup at {} local shards vs \
         1: {speedup:.2}x; remote rungs: {LOOPBACK_SHARDS}-shard TCP \
         loopback ring + {LOOPBACK_SHARDS}-shard failover ring (dead \
         primaries, replicas serve) + {LOOPBACK_SHARDS}-shard multiplex \
         ring (2 concurrent batch drivers, one shared RingClient, \
         {multiplex_hwm} waves high-water on one connection), answers \
         asserted identical to local; tcp-deadline rung reports \
         end-to-end queries/s through a full query server and counted \
         {rung_shed} shed / {rung_exceeded} deadline-exceeded answers; \
         http-front rung drives the HTTP/1.1 front door with the result \
         cache on and counted {http_shed} clean 429s under saturation \
         plus {http_hits} byte-identical cache hits across an epoch \
         flip; tcp-reshard rung doubled the ring live (placement epoch \
         {re_from} -> {re_to}) with bitwise-identical answers on both \
         sides of the flip; tcp-speculate rung ran the workload with \
         cross-round speculation off then on, answers bitwise-identical \
         both ways, {spec_confirmed} speculated pulls confirmed",
        SHARD_COUNTS[SHARD_COUNTS.len() - 1]));
    let kernel_note = kernel_runs
        .iter()
        .map(|k| format!("{} {:.0} rows/s ({:.2}x)", k.tier,
                         k.rows_per_s, k.speedup_vs_scalar))
        .collect::<Vec<_>>()
        .join(", ");
    rep.note(&format!(
        "dispatched kernel tier: {dispatched}; single-core partial_sums \
         by tier: {kernel_note}"));
    let json = Json::obj(vec![
        ("workload", Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("d", Json::Num(d as f64)),
            ("batch_queries", Json::Num(batch as f64)),
            ("batch_reps", Json::Num(reps as f64)),
            ("solo_queries", Json::Num(solo_q as f64)),
            ("smoke", Json::Bool(smoke)),
            ("seed", Json::Num(seed as f64)),
        ])),
        ("shards", Json::Arr(local_runs.iter().map(run_json).collect())),
        ("remote", Json::Arr(remote_runs.iter().map(run_json).collect())),
        ("kernel_tiers", Json::Arr(kernel_runs
            .iter()
            .map(|k| Json::obj(vec![
                ("tier", Json::Str(k.tier.to_string())),
                ("pull_rows_per_s", Json::Num(k.rows_per_s)),
                ("speedup_vs_scalar", Json::Num(k.speedup_vs_scalar)),
            ]))
            .collect())),
        ("dispatched_tier", Json::Str(dispatched.to_string())),
        ("speedup_pull_max_vs_1", Json::Num(speedup)),
    ]);
    Ok((rep, json))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_reports_consistent_nonzero_numbers() {
        let (rep, json) = run_pull_bench(true, 7, &[]).unwrap();
        assert_eq!(rep.rows.len(), SHARD_COUNTS.len() + 7);
        let shards = json.get("shards").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(shards.len(), SHARD_COUNTS.len());
        let remote = json.get("remote").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(remote.len(), 7,
                   "loopback + failover + multiplex + deadline + \
                    http-front + reshard + speculate rungs always \
                    present");
        assert_eq!(remote[1].get("transport").and_then(|v| v.as_str()),
                   Some("tcp-failover"));
        assert_eq!(remote[2].get("transport").and_then(|v| v.as_str()),
                   Some("tcp-multiplex"));
        let mi = remote[2]
            .get("max_inflight")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(mi >= 2.0,
                "multiplex rung must witness >= 2 in-flight waves on one \
                 connection, saw {mi}");
        assert_eq!(remote[3].get("transport").and_then(|v| v.as_str()),
                   Some("tcp-deadline"));
        let shed = remote[3].get("shed").and_then(|v| v.as_f64()).unwrap();
        let de = remote[3]
            .get("deadline_exceeded")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(shed >= 1.0, "deadline rung must shed, saw {shed}");
        assert!(de >= 1.0,
                "deadline rung must expire probe budgets, saw {de}");
        assert_eq!(remote[4].get("transport").and_then(|v| v.as_str()),
                   Some("http-front"));
        let http_shed =
            remote[4].get("shed").and_then(|v| v.as_f64()).unwrap();
        let hits = remote[4]
            .get("cache_hits")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(http_shed >= 1.0,
                "http-front rung must answer clean 429s under \
                 saturation, saw {http_shed}");
        assert!(hits >= 1.0,
                "http-front rung must witness a byte-identical cache \
                 hit, saw {hits}");
        assert_eq!(remote[5].get("transport").and_then(|v| v.as_str()),
                   Some("tcp-reshard"));
        let e_from = remote[5]
            .get("epoch_from")
            .and_then(|v| v.as_f64())
            .unwrap();
        let e_to = remote[5]
            .get("epoch_to")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(e_from, 0.0,
                   "reshard rung starts on the default epoch-0 ring");
        assert!(e_to >= 1.0,
                "reshard rung must advance the placement epoch, saw \
                 {e_from} -> {e_to}");
        assert_eq!(remote[6].get("transport").and_then(|v| v.as_str()),
                   Some("tcp-speculate"));
        let sc = remote[6]
            .get("spec_confirmed")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(sc >= 1.0,
                "speculate rung must confirm at least one speculated \
                 pull, saw {sc}");
        for s in shards.iter().chain(remote) {
            let rps = s.get("pull_rows_per_s")
                .and_then(|v| v.as_f64())
                .unwrap();
            assert!(rps > 0.0 && rps.is_finite(), "rows/s {rps}");
            assert!(s.get("pull_rounds").and_then(|v| v.as_f64()).unwrap()
                    > 0.0);
            assert!(s.get("transport").and_then(|v| v.as_str()).is_some());
        }
        // kernel-tier rung: scalar anchor always present and nonzero;
        // the dispatched tier names a real tier
        let tiers =
            json.get("kernel_tiers").and_then(|s| s.as_arr()).unwrap();
        assert!(!tiers.is_empty());
        assert_eq!(tiers[0].get("tier").and_then(|v| v.as_str()),
                   Some("scalar"));
        for t in tiers {
            let rps = t.get("pull_rows_per_s")
                .and_then(|v| v.as_f64())
                .unwrap();
            assert!(rps > 0.0 && rps.is_finite(), "kernel rows/s {rps}");
            assert!(t.get("speedup_vs_scalar")
                        .and_then(|v| v.as_f64())
                        .unwrap() > 0.0);
        }
        let dispatched =
            json.get("dispatched_tier").and_then(|v| v.as_str()).unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&dispatched));
        // round-trips through the parser (what the CI step asserts)
        let text = json.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("speedup_pull_max_vs_1").is_some());
        assert!(parsed.get("kernel_tiers").is_some());
    }

    #[test]
    fn timing_engine_is_transparent() {
        use crate::runtime::native::NativeEngine;
        let ds = synthetic::gaussian_iid(8, 32, 3);
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (1..8).collect();
        let coords: Vec<u32> = vec![0, 5, 9, 13, 30];
        let req = PullRequest { query: &q, rows: &rows,
                                coord_ids: &coords };
        let mut timed = TimingEngine::new(NativeEngine::default());
        let mut plain = NativeEngine::default();
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        timed.pull_batch(&ds, &[req], Metric::L2Sq, &mut s1, &mut q1);
        plain.pull_batch(&ds, &[req], Metric::L2Sq, &mut s2, &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
        assert_eq!(timed.pull_calls, 1);
        assert_eq!(timed.pull_jobs, 7);
    }
}
