//! Exact brute-force k-NN — the paper's "exact computation" baseline
//! (scikit-learn NearestNeighbors stand-in). Costs exactly n·d units per
//! query for dense data and Σ(|S_q|+|S_i|) for sparse.

use crate::data::dense::{DenseDataset, Metric};
use crate::data::sparse::SparseDataset;
use crate::metrics::Counter;

#[derive(Clone, Debug)]
pub struct ExactResult {
    pub ids: Vec<u32>,
    pub dists: Vec<f64>,
}

/// Smallest-k selection by binary-heap of size k.
fn top_k(dists: impl Iterator<Item = (f64, u32)>, k: usize) -> ExactResult {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    // max-heap of the k best so far, keyed by distance
    let mut heap: BinaryHeap<(OrdF64, u32)> = BinaryHeap::with_capacity(k + 1);
    for (d, i) in dists {
        if heap.len() < k {
            heap.push((OrdF64(d), i));
        } else if let Some(&(OrdF64(worst), _)) = heap.peek() {
            if d < worst {
                heap.pop();
                heap.push((OrdF64(d), i));
            }
        }
    }
    let mut v: Vec<(f64, u32)> =
        heap.into_iter().map(|(OrdF64(d), i)| (d, i)).collect();
    v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let _ = Reverse(0); // silence unused-import pattern on old compilers
    ExactResult {
        ids: v.iter().map(|&(_, i)| i).collect(),
        dists: v.iter().map(|&(d, _)| d).collect(),
    }
}

#[derive(PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&o.0)
    }
}

/// k-NN of dataset point `q` (self excluded).
pub fn knn_point(data: &DenseDataset, q: usize, k: usize, metric: Metric,
                 counter: &mut Counter) -> ExactResult {
    let qrow = data.row(q);
    top_k(
        (0..data.n).filter(|&i| i != q).map(|i| {
            counter.add(data.d as u64);
            (crate::data::dense::dist_slices(data.row(i), qrow, metric),
             i as u32)
        }),
        k,
    )
}

/// k-NN of an external query.
pub fn knn_query(data: &DenseDataset, query: &[f32], k: usize,
                 metric: Metric, counter: &mut Counter) -> ExactResult {
    top_k(
        (0..data.n).map(|i| {
            counter.add(data.d as u64);
            (crate::data::dense::dist_slices(data.row(i), query, metric),
             i as u32)
        }),
        k,
    )
}

/// Sparse-aware exact k-NN (merge-based distances; cost |S_q|+|S_i| per
/// pair — the baseline of Fig 4b, which "takes sparsity into account").
pub fn knn_point_sparse(data: &SparseDataset, q: usize, k: usize,
                        metric: Metric, counter: &mut Counter)
                        -> ExactResult {
    top_k(
        (0..data.n)
            .filter(|&i| i != q)
            .map(|i| (data.dist(q, i, metric, counter), i as u32)),
        k,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn finds_true_neighbors() {
        let ds = synthetic::gaussian_iid(30, 16, 61);
        let mut c = Counter::new();
        let res = knn_point(&ds, 0, 3, Metric::L2Sq, &mut c);
        assert_eq!(res.ids.len(), 3);
        // verify against a full sort
        let mut all: Vec<(f64, u32)> = (1..30)
            .map(|i| (ds.dist(0, i, Metric::L2Sq, &mut Counter::new()),
                      i as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert_eq!(res.ids,
                   all[..3].iter().map(|&(_, i)| i).collect::<Vec<_>>());
        // cost accounting: (n-1)·d
        assert_eq!(c.get(), 29 * 16);
    }

    #[test]
    fn dists_sorted_ascending() {
        let ds = synthetic::gaussian_iid(50, 8, 62);
        let mut c = Counter::new();
        let res = knn_query(&ds, ds.row(10), 5, Metric::L1, &mut c);
        for w in res.dists.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // self-query: point 10 itself is in the data, distance 0
        assert_eq!(res.ids[0], 10);
    }

    #[test]
    fn sparse_exact_costs_by_support() {
        let ds = synthetic::rna_like(20, 500, 0.1, 63);
        let mut c = Counter::new();
        let _ = knn_point_sparse(&ds, 0, 3, Metric::L1, &mut c);
        let expect: u64 = (1..20)
            .map(|i| (ds.nnz(0) + ds.nnz(i)) as u64)
            .sum();
        assert_eq!(c.get(), expect);
        assert!(c.get() < 19 * 500, "sparse cost must beat dense n·d");
    }

    #[test]
    fn k_larger_than_candidates_returns_all() {
        let ds = synthetic::gaussian_iid(4, 8, 64);
        let mut c = Counter::new();
        let res = knn_point(&ds, 0, 10, Metric::L2Sq, &mut c);
        assert_eq!(res.ids.len(), 3);
    }
}
