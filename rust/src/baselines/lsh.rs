//! Locality-sensitive hashing baseline (FALCONN stand-in, Fig 3 / Fig 6).
//!
//! p-stable LSH: each of `L` tables hashes a point with `K` concatenated
//! quantized projections h(x) = floor((a·x + b)/w), a ~ N(0,1)^d for ℓ2
//! (Datar et al.) or Cauchy^d for ℓ1. A query's candidate set is the union
//! of its buckets across tables; exact distances are then computed on the
//! candidates.
//!
//! Cost accounting follows the paper's Appendix D exactly: "we lower bound
//! the number of coordinate-wise distance computations LSH makes as
//! d × size of candidate set" — hashing and table lookups are free (index
//! cost is excluded for all baselines).

use std::collections::HashMap;

use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct LshParams {
    /// number of hash tables (recall knob)
    pub n_tables: usize,
    /// hashes concatenated per table (precision knob)
    pub n_hashes: usize,
    /// quantization width
    pub w: f64,
}

impl Default for LshParams {
    fn default() -> Self {
        LshParams { n_tables: 16, n_hashes: 8, w: 4.0 }
    }
}

struct HashFn {
    /// projection vectors, row-major [n_hashes][d]
    a: Vec<f64>,
    b: Vec<f64>,
    w: f64,
    n_hashes: usize,
    d: usize,
}

impl HashFn {
    fn sample(d: usize, n_hashes: usize, w: f64, metric: Metric,
              rng: &mut Rng) -> Self {
        let a = (0..n_hashes * d)
            .map(|_| match metric {
                Metric::L2Sq => rng.gaussian(),
                Metric::L1 => rng.cauchy(),
            })
            .collect();
        let b = (0..n_hashes).map(|_| rng.f64() * w).collect();
        HashFn { a, b, w, n_hashes, d }
    }

    /// Rescale the quantization width (data-driven tuning).
    fn set_w(&mut self, w: f64, rng: &mut Rng) {
        self.w = w;
        for b in self.b.iter_mut() {
            *b = rng.f64() * w;
        }
    }

    fn key(&self, x: &[f32]) -> u64 {
        // FNV-combine the quantized projections
        let mut key = 0xcbf2_9ce4_8422_2325u64;
        for h in 0..self.n_hashes {
            let row = &self.a[h * self.d..(h + 1) * self.d];
            let mut dot = self.b[h];
            for (ai, xi) in row.iter().zip(x) {
                dot += ai * *xi as f64;
            }
            let q = (dot / self.w).floor() as i64;
            key ^= q as u64;
            key = key.wrapping_mul(0x1000_0000_01b3);
        }
        key
    }
}

pub struct LshIndex<'a> {
    data: &'a DenseDataset,
    metric: Metric,
    funcs: Vec<HashFn>,
    tables: Vec<HashMap<u64, Vec<u32>>>,
}

impl<'a> LshIndex<'a> {
    /// Build the index (NOT counted — the paper excludes index
    /// construction for all baselines).
    ///
    /// The quantization width is data-driven: `params.w` is interpreted as
    /// a *fraction* of the projection spread (std of `a·x` over a sample
    /// of points). A fixed absolute width collapses at high d, where
    /// projection magnitudes grow like √d and every point lands in its
    /// own bucket.
    pub fn build(data: &'a DenseDataset, metric: Metric, params: &LshParams,
                 rng: &mut Rng) -> Self {
        let mut funcs: Vec<HashFn> = (0..params.n_tables)
            .map(|_| HashFn::sample(data.d, params.n_hashes, params.w,
                                    metric, rng))
            .collect();
        // estimate projection spread on the first hash of the first table
        if !funcs.is_empty() {
            let f = &funcs[0];
            let sample = 64.min(data.n);
            let mut vals = Vec::with_capacity(sample);
            for i in 0..sample {
                let row = data.row(i * data.n / sample);
                let mut dot = 0f64;
                for (ai, xi) in f.a[..f.d].iter().zip(row) {
                    dot += ai * *xi as f64;
                }
                vals.push(dot);
            }
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean))
                .sum::<f64>() / vals.len().max(1) as f64;
            let spread = var.sqrt().max(1e-9);
            let w_abs = (params.w / 4.0) * spread; // w=4.0 default ≙ 1·σ
            for f in funcs.iter_mut() {
                f.set_w(w_abs, rng);
            }
        }
        let mut tables: Vec<HashMap<u64, Vec<u32>>> =
            (0..params.n_tables).map(|_| HashMap::new()).collect();
        for i in 0..data.n {
            let row = data.row(i);
            for (f, t) in funcs.iter().zip(tables.iter_mut()) {
                t.entry(f.key(row)).or_default().push(i as u32);
            }
        }
        LshIndex { data, metric, funcs, tables }
    }

    /// Collect the candidate set for a query (deduplicated).
    pub fn candidates(&self, query: &[f32], exclude: Option<usize>)
                      -> Vec<u32> {
        let mut seen = std::collections::HashSet::new();
        for (f, t) in self.funcs.iter().zip(&self.tables) {
            if let Some(bucket) = t.get(&f.key(query)) {
                for &i in bucket {
                    if Some(i as usize) != exclude {
                        seen.insert(i);
                    }
                }
            }
        }
        seen.into_iter().collect()
    }

    /// k-NN query: exact distances on the candidate set.
    /// Charged `d × |candidates|` (Appendix D accounting).
    pub fn knn_query(&self, query: &[f32], exclude: Option<usize>, k: usize,
                     counter: &mut Counter) -> Vec<(u32, f64)> {
        let cands = self.candidates(query, exclude);
        counter.add(cands.len() as u64 * self.data.d as u64);
        let mut scored: Vec<(f64, u32)> = cands
            .into_iter()
            .map(|i| {
                (crate::data::dense::dist_slices(
                    self.data.row(i as usize), query, self.metric),
                 i)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored.truncate(k);
        scored.into_iter().map(|(d, i)| (i, d)).collect()
    }
}

/// Tune `n_tables` upward until the index reaches `target_recall` on a
/// sample of self-queries (mirrors the paper tuning FALCONN's probes to
/// 99% accuracy). Returns the tuned index.
pub fn build_tuned<'a>(data: &'a DenseDataset, metric: Metric, k: usize,
                       target_recall: f64, rng: &mut Rng)
                       -> (LshIndex<'a>, LshParams) {
    let mut params = LshParams::default();
    loop {
        let idx = LshIndex::build(data, metric, &params, rng);
        let recall = measure_recall(&idx, data, metric, k, rng);
        if recall >= target_recall || params.n_tables >= 256 {
            return (idx, params);
        }
        params.n_tables *= 2;
    }
}

fn measure_recall(idx: &LshIndex, data: &DenseDataset, metric: Metric,
                  k: usize, rng: &mut Rng) -> f64 {
    let trials = 30.min(data.n);
    let mut hit = 0usize;
    for _ in 0..trials {
        let q = rng.below(data.n);
        let truth = crate::baselines::exact::knn_point(
            data, q, k, metric, &mut Counter::new());
        let got = idx.knn_query(data.row(q), Some(q), k,
                                &mut Counter::new());
        let gs: std::collections::HashSet<u32> =
            got.iter().map(|&(i, _)| i).collect();
        if truth.ids.iter().all(|i| gs.contains(i)) {
            hit += 1;
        }
    }
    hit as f64 / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn lsh_finds_near_duplicates() {
        let mut ds = synthetic::gaussian_iid(100, 64, 81);
        // plant a near-duplicate of point 0 at point 1
        let row0 = ds.row_vec(0);
        for (j, v) in ds.row_mut(1).iter_mut().enumerate() {
            *v = row0[j] + 0.001;
        }
        let mut rng = Rng::new(82);
        let idx = LshIndex::build(&ds, Metric::L2Sq, &LshParams::default(),
                                  &mut rng);
        let mut c = Counter::new();
        let res = idx.knn_query(ds.row(0), Some(0), 1, &mut c);
        assert_eq!(res[0].0, 1);
        assert!(c.get() > 0);
    }

    #[test]
    fn candidate_cost_accounting() {
        let ds = synthetic::gaussian_iid(50, 32, 83);
        let mut rng = Rng::new(84);
        let idx = LshIndex::build(&ds, Metric::L2Sq, &LshParams::default(),
                                  &mut rng);
        let cands = idx.candidates(ds.row(5), Some(5));
        let mut c = Counter::new();
        let _ = idx.knn_query(ds.row(5), Some(5), 3, &mut c);
        assert_eq!(c.get(), cands.len() as u64 * 32);
    }

    #[test]
    fn more_tables_higher_recall() {
        let ds = synthetic::image_like(200, 128, 85);
        let mut rng = Rng::new(86);
        let small = LshIndex::build(
            &ds, Metric::L2Sq,
            &LshParams { n_tables: 2, n_hashes: 8, w: 4.0 }, &mut rng);
        let mut rng2 = Rng::new(86);
        let big = LshIndex::build(
            &ds, Metric::L2Sq,
            &LshParams { n_tables: 32, n_hashes: 8, w: 4.0 }, &mut rng2);
        let mut rng3 = Rng::new(87);
        let r_small =
            measure_recall(&small, &ds, Metric::L2Sq, 1, &mut rng3);
        let mut rng4 = Rng::new(87);
        let r_big = measure_recall(&big, &ds, Metric::L2Sq, 1, &mut rng4);
        assert!(r_big >= r_small,
                "recall should not drop with more tables: {r_small} -> {r_big}");
    }

    #[test]
    fn tuned_index_reaches_target() {
        let ds = synthetic::image_like(150, 64, 88);
        let mut rng = Rng::new(89);
        let (idx, params) =
            build_tuned(&ds, Metric::L2Sq, 1, 0.9, &mut rng);
        let mut rng2 = Rng::new(90);
        let recall = measure_recall(&idx, &ds, Metric::L2Sq, 1, &mut rng2);
        assert!(recall >= 0.8, "tuned recall {recall} (L={})",
                params.n_tables);
    }

    #[test]
    fn l1_variant_runs() {
        let ds = synthetic::gaussian_iid(60, 32, 91);
        let mut rng = Rng::new(92);
        let idx = LshIndex::build(&ds, Metric::L1, &LshParams::default(),
                                  &mut rng);
        let mut c = Counter::new();
        let res = idx.knn_query(ds.row(3), Some(3), 2, &mut c);
        assert!(res.len() <= 2);
    }
}
