//! NGT-style baseline: ANNG incremental proximity-graph construction
//! (Iwasaki & Miyazaki) + beam-search querying.
//!
//! Unlike NN-descent (batch refinement), ANNG inserts points one at a
//! time: each new point is located with a search over the graph built so
//! far, then connected bidirectionally to its approximate nearest
//! neighbors. This gives a navigable graph with asymmetric degree growth,
//! like NGT's default index.

use crate::baselines::graph::{beam_search, ProximityGraph};
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct AnngParams {
    /// out-edges added per inserted point
    pub edges: usize,
    /// beam width during construction searches
    pub build_ef: usize,
    /// max out-degree (older nodes accumulate reverse edges)
    pub max_degree: usize,
    /// beam width at query time
    pub ef: usize,
    pub n_seeds: usize,
}

impl Default for AnngParams {
    fn default() -> Self {
        AnngParams { edges: 12, build_ef: 32, max_degree: 32, ef: 72,
                     n_seeds: 12 }
    }
}

pub struct AnngIndex<'a> {
    data: &'a DenseDataset,
    metric: Metric,
    pub graph: ProximityGraph,
    params: AnngParams,
}

impl<'a> AnngIndex<'a> {
    pub fn build(data: &'a DenseDataset, metric: Metric, params: AnngParams,
                 rng: &mut Rng) -> Self {
        let n = data.n;
        let mut free = Counter::new(); // construction not charged
        let mut neighbors: Vec<Vec<u32>> = vec![Vec::new(); n];
        // insert points one at a time in random order
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut inserted: Vec<u32> = Vec::with_capacity(n);
        for &p in &order {
            if inserted.len() < params.edges + 1 {
                // bootstrap: fully connect the first few points
                for &q in &inserted {
                    neighbors[p].push(q);
                    neighbors[q as usize].push(p as u32);
                }
                inserted.push(p as u32);
                continue;
            }
            // locate approximate neighbors with a search over the partial
            // graph, seeded from random inserted points
            let partial = PartialView { neighbors: &neighbors };
            let found = partial.search(
                data, &inserted, data.row(p), params.edges, params.build_ef,
                metric, rng, &mut free,
            );
            for (q, _) in found {
                neighbors[p].push(q);
                if neighbors[q as usize].len() < params.max_degree {
                    neighbors[q as usize].push(p as u32);
                }
            }
            inserted.push(p as u32);
        }
        AnngIndex {
            data,
            metric,
            graph: ProximityGraph { neighbors },
            params,
        }
    }

    pub fn knn_query(&self, query: &[f32], exclude: Option<usize>, k: usize,
                     rng: &mut Rng, counter: &mut Counter)
                     -> Vec<(u32, f64)> {
        beam_search(&self.graph, self.data, query, exclude, k,
                    self.params.ef, self.params.n_seeds, self.metric, rng,
                    counter)
    }
}

/// Beam search over a partially-built graph (seeds restricted to the
/// inserted set).
struct PartialView<'g> {
    neighbors: &'g [Vec<u32>],
}

impl<'g> PartialView<'g> {
    #[allow(clippy::too_many_arguments)]
    fn search(&self, data: &DenseDataset, inserted: &[u32], query: &[f32],
              k: usize, ef: usize, metric: Metric, rng: &mut Rng,
              counter: &mut Counter) -> Vec<(u32, f64)> {
        use std::collections::HashSet;
        let mut visited: HashSet<u32> = HashSet::new();
        let mut pool: Vec<(f64, u32)> = Vec::new();
        let mut frontier: Vec<(f64, u32)> = Vec::new();
        for _ in 0..4 {
            let s = inserted[rng.below(inserted.len())];
            if visited.insert(s) {
                counter.add(data.d as u64);
                let d = crate::data::dense::dist_slices(
                    data.row(s as usize), query, metric);
                pool.push((d, s));
                frontier.push((d, s));
            }
        }
        while let Some(idx) = frontier
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .map(|(i, _)| i)
        {
            let (dc, c) = frontier.swap_remove(idx);
            pool.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            pool.truncate(ef);
            if pool.len() >= ef && dc > pool.last().unwrap().0 {
                break;
            }
            for &nb in &self.neighbors[c as usize] {
                if visited.insert(nb) {
                    counter.add(data.d as u64);
                    let d = crate::data::dense::dist_slices(
                        data.row(nb as usize), query, metric);
                    pool.push((d, nb));
                    frontier.push((d, nb));
                }
            }
        }
        pool.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        pool.truncate(k);
        pool.into_iter().map(|(d, i)| (i, d)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn anng_query_finds_true_nn() {
        let ds = synthetic::image_like(250, 96, 121);
        let mut rng = Rng::new(122);
        let idx = AnngIndex::build(&ds, Metric::L2Sq, AnngParams::default(),
                                   &mut rng);
        let mut hits = 0usize;
        let mut c = Counter::new();
        let trials = 25;
        for q in 0..trials {
            let truth = crate::baselines::exact::knn_point(
                &ds, q, 1, Metric::L2Sq, &mut Counter::new());
            let got = idx.knn_query(ds.row(q), Some(q), 1, &mut rng, &mut c);
            hits += (got[0].0 == truth.ids[0]) as usize;
        }
        assert!(hits >= 21, "hits {hits}/{trials}");
    }

    #[test]
    fn cost_is_sublinear_in_n() {
        let ds = synthetic::image_like(400, 64, 123);
        let mut rng = Rng::new(124);
        let idx = AnngIndex::build(&ds, Metric::L2Sq, AnngParams::default(),
                                   &mut rng);
        let mut c = Counter::new();
        let trials = 20;
        for q in 0..trials {
            let _ = idx.knn_query(ds.row(q), Some(q), 5, &mut rng, &mut c);
        }
        let per_query = c.get() / trials as u64;
        let brute = 399 * 64;
        assert!(per_query < brute / 2,
                "per-query {per_query} vs brute {brute}");
    }

    #[test]
    fn graph_is_connected_enough() {
        let ds = synthetic::gaussian_iid(100, 16, 125);
        let mut rng = Rng::new(126);
        let idx = AnngIndex::build(&ds, Metric::L2Sq, AnngParams::default(),
                                   &mut rng);
        let (min_deg, _, mean_deg) = idx.graph.degree_stats();
        assert!(min_deg >= 1, "isolated node (min degree 0)");
        assert!(mean_deg >= 5.0, "mean degree {mean_deg}");
    }
}
