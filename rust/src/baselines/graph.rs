//! Shared proximity-graph machinery for the kGraph-style (nndescent) and
//! NGT-style (graph_search) baselines: the graph container and the
//! best-first beam search used at query time.
//!
//! Query-time distance evaluations are charged d units each; index
//! construction is NOT counted (the paper's accounting, Appendix D).

use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

/// Directed k-NN graph: `neighbors[i]` are point i's out-edges.
#[derive(Clone, Debug)]
pub struct ProximityGraph {
    pub neighbors: Vec<Vec<u32>>,
}

impl ProximityGraph {
    pub fn degree_stats(&self) -> (usize, usize, f64) {
        let degs: Vec<usize> = self.neighbors.iter().map(|v| v.len()).collect();
        let min = degs.iter().copied().min().unwrap_or(0);
        let max = degs.iter().copied().max().unwrap_or(0);
        let mean = degs.iter().sum::<usize>() as f64 / degs.len().max(1) as f64;
        (min, max, mean)
    }
}

#[derive(PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&o.0)
    }
}

/// Best-first beam search over a proximity graph.
///
/// Maintains a result pool of size `ef`; expands the closest unexpanded
/// candidate until the pool stabilizes. Every distance evaluation charges
/// `d` units. Returns the k best (id, dist) found.
pub fn beam_search(
    graph: &ProximityGraph,
    data: &DenseDataset,
    query: &[f32],
    exclude: Option<usize>,
    k: usize,
    ef: usize,
    n_seeds: usize,
    metric: Metric,
    rng: &mut Rng,
    counter: &mut Counter,
) -> Vec<(u32, f64)> {
    use std::cmp::Reverse;
    use std::collections::{BinaryHeap, HashSet};
    let n = data.n;
    let ef = ef.max(k);
    let mut visited: HashSet<u32> = HashSet::new();
    // candidates: min-heap by distance; pool: max-heap of current best ef
    let mut cand: BinaryHeap<Reverse<(OrdF64, u32)>> = BinaryHeap::new();
    let mut pool: BinaryHeap<(OrdF64, u32)> = BinaryHeap::new();

    let eval = |i: u32, counter: &mut Counter| -> f64 {
        counter.add(data.d as u64);
        crate::data::dense::dist_slices(data.row(i as usize), query, metric)
    };

    for _ in 0..n_seeds.max(1) {
        let s = rng.below(n) as u32;
        if Some(s as usize) == exclude || !visited.insert(s) {
            continue;
        }
        let d = eval(s, counter);
        cand.push(Reverse((OrdF64(d), s)));
        pool.push((OrdF64(d), s));
    }
    while pool.len() > ef {
        pool.pop();
    }

    while let Some(Reverse((OrdF64(dc), c))) = cand.pop() {
        // stop when the closest candidate is worse than the pool's worst
        if pool.len() >= ef {
            if let Some(&(OrdF64(worst), _)) = pool.peek() {
                if dc > worst {
                    break;
                }
            }
        }
        for &nb in &graph.neighbors[c as usize] {
            if Some(nb as usize) == exclude || !visited.insert(nb) {
                continue;
            }
            let d = eval(nb, counter);
            let admit = pool.len() < ef
                || pool.peek().map(|&(OrdF64(w), _)| d < w).unwrap_or(true);
            if admit {
                cand.push(Reverse((OrdF64(d), nb)));
                pool.push((OrdF64(d), nb));
                if pool.len() > ef {
                    pool.pop();
                }
            }
        }
    }

    let mut out: Vec<(f64, u32)> =
        pool.into_iter().map(|(OrdF64(d), i)| (d, i)).collect();
    out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    out.truncate(k);
    out.into_iter().map(|(d, i)| (i, d)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    /// exact graph for testing the search itself
    fn exact_graph(data: &DenseDataset, deg: usize) -> ProximityGraph {
        let mut c = Counter::new();
        let neighbors = (0..data.n)
            .map(|i| {
                crate::baselines::exact::knn_point(
                    data, i, deg, Metric::L2Sq, &mut c)
                .ids
            })
            .collect();
        ProximityGraph { neighbors }
    }

    #[test]
    fn beam_search_on_exact_graph_finds_nn() {
        let ds = synthetic::image_like(150, 64, 101);
        let g = exact_graph(&ds, 8);
        let mut rng = Rng::new(102);
        let mut c = Counter::new();
        let mut hits = 0;
        for q in 0..20 {
            let truth = crate::baselines::exact::knn_point(
                &ds, q, 1, Metric::L2Sq, &mut Counter::new());
            let got = beam_search(&g, &ds, ds.row(q), Some(q), 1, 32, 8,
                                  Metric::L2Sq, &mut rng, &mut c);
            if got[0].0 == truth.ids[0] {
                hits += 1;
            }
        }
        assert!(hits >= 18, "hits {hits}/20");
    }

    #[test]
    fn beam_search_counts_distance_evals() {
        let ds = synthetic::gaussian_iid(50, 16, 103);
        let g = exact_graph(&ds, 4);
        let mut rng = Rng::new(104);
        let mut c = Counter::new();
        let _ = beam_search(&g, &ds, ds.row(0), Some(0), 1, 8, 4,
                            Metric::L2Sq, &mut rng, &mut c);
        assert!(c.get() > 0);
        assert_eq!(c.get() % 16, 0, "cost must be a multiple of d");
        // visits far fewer than all points on a connected graph... but at
        // n=50 it may visit most; just verify it's bounded by n·d
        assert!(c.get() <= 50 * 16);
    }

    #[test]
    fn excluded_point_never_returned() {
        let ds = synthetic::gaussian_iid(30, 8, 105);
        let g = exact_graph(&ds, 4);
        let mut rng = Rng::new(106);
        let mut c = Counter::new();
        let got = beam_search(&g, &ds, ds.row(3), Some(3), 5, 16, 8,
                              Metric::L2Sq, &mut rng, &mut c);
        assert!(got.iter().all(|&(i, _)| i != 3));
    }
}
