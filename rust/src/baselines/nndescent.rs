//! kGraph-style baseline: NN-descent graph construction (Dong et al.) +
//! beam-search querying. Matches the algorithmic family of kGraph [8]:
//! improve sample complexity in *n* by exploiting "the neighborhoods of
//! neighboring points have large intersections".
//!
//! Index construction is not counted (Appendix D); query-time distance
//! evaluations cost d each via `graph::beam_search`.

use crate::baselines::graph::{beam_search, ProximityGraph};
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct NnDescentParams {
    /// graph degree (K in kGraph)
    pub degree: usize,
    /// max NN-descent iterations
    pub iters: usize,
    /// sample size of new candidates per point per iteration (ρ·K)
    pub sample: usize,
    /// beam width at query time
    pub ef: usize,
    /// random seeds at query time
    pub n_seeds: usize,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        NnDescentParams { degree: 20, iters: 12, sample: 16, ef: 120,
                          n_seeds: 20 }
    }
}

struct HeapEntry {
    dist: f64,
    id: u32,
    new: bool,
}

/// Per-point bounded max-heap of current best neighbors.
struct NeighborHeap {
    entries: Vec<HeapEntry>, // kept sorted ascending by dist, small K
    cap: usize,
}

impl NeighborHeap {
    fn new(cap: usize) -> Self {
        NeighborHeap { entries: Vec::with_capacity(cap + 1), cap }
    }

    fn worst(&self) -> f64 {
        self.entries.last().map(|e| e.dist).unwrap_or(f64::INFINITY)
    }

    fn contains(&self, id: u32) -> bool {
        self.entries.iter().any(|e| e.id == id)
    }

    /// Insert if better than the current worst; returns true on update.
    fn push(&mut self, id: u32, dist: f64) -> bool {
        if self.entries.len() >= self.cap && dist >= self.worst() {
            return false;
        }
        if self.contains(id) {
            return false;
        }
        let pos = self
            .entries
            .partition_point(|e| e.dist < dist);
        self.entries.insert(pos, HeapEntry { dist, id, new: true });
        if self.entries.len() > self.cap {
            self.entries.pop();
        }
        true
    }
}

pub struct NnDescentIndex<'a> {
    data: &'a DenseDataset,
    metric: Metric,
    pub graph: ProximityGraph,
    params: NnDescentParams,
}

impl<'a> NnDescentIndex<'a> {
    /// NN-descent construction (local joins over neighbor ∪ reverse-
    /// neighbor sets until convergence).
    pub fn build(data: &'a DenseDataset, metric: Metric,
                 params: NnDescentParams, rng: &mut Rng) -> Self {
        let n = data.n;
        let k = params.degree.min(n.saturating_sub(1)).max(1);
        let mut free = Counter::new(); // construction not charged
        let mut heaps: Vec<NeighborHeap> =
            (0..n).map(|_| NeighborHeap::new(k)).collect();
        // random init
        for i in 0..n {
            while heaps[i].entries.len() < k {
                let j = rng.below(n);
                if j == i {
                    continue;
                }
                let d = data.dist(i, j, metric, &mut free);
                heaps[i].push(j as u32, d);
            }
        }
        // descent iterations
        for _ in 0..params.iters {
            // gather new forward/reverse candidates
            let mut new_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
            let mut old_cand: Vec<Vec<u32>> = vec![Vec::new(); n];
            for i in 0..n {
                for e in heaps[i].entries.iter() {
                    if e.new {
                        new_cand[i].push(e.id);
                        new_cand[e.id as usize].push(i as u32);
                    } else {
                        old_cand[i].push(e.id);
                        old_cand[e.id as usize].push(i as u32);
                    }
                }
            }
            for i in 0..n {
                for e in heaps[i].entries.iter_mut() {
                    e.new = false;
                }
            }
            // subsample candidate lists
            for lists in [&mut new_cand, &mut old_cand] {
                for l in lists.iter_mut() {
                    l.sort_unstable();
                    l.dedup();
                    if l.len() > params.sample {
                        rng.shuffle(l);
                        l.truncate(params.sample);
                    }
                }
            }
            // local joins: new×new and new×old
            let mut updates = 0usize;
            for i in 0..n {
                let news = new_cand[i].clone();
                let olds = old_cand[i].clone();
                for (ai, &u) in news.iter().enumerate() {
                    for &v in news.iter().skip(ai + 1) {
                        if u == v {
                            continue;
                        }
                        let d = data.dist(u as usize, v as usize, metric,
                                          &mut free);
                        updates += heaps[u as usize].push(v, d) as usize;
                        updates += heaps[v as usize].push(u, d) as usize;
                    }
                    for &v in &olds {
                        if u == v {
                            continue;
                        }
                        let d = data.dist(u as usize, v as usize, metric,
                                          &mut free);
                        updates += heaps[u as usize].push(v, d) as usize;
                        updates += heaps[v as usize].push(u, d) as usize;
                    }
                }
            }
            if updates == 0 {
                break;
            }
        }
        let neighbors = heaps
            .into_iter()
            .map(|h| h.entries.into_iter().map(|e| e.id).collect())
            .collect();
        NnDescentIndex {
            data,
            metric,
            graph: ProximityGraph { neighbors },
            params,
        }
    }

    /// k-NN query; distance evaluations charged d each.
    pub fn knn_query(&self, query: &[f32], exclude: Option<usize>, k: usize,
                     rng: &mut Rng, counter: &mut Counter)
                     -> Vec<(u32, f64)> {
        beam_search(&self.graph, self.data, query, exclude, k,
                    self.params.ef, self.params.n_seeds, self.metric, rng,
                    counter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn graph_converges_to_true_neighbors() {
        let ds = synthetic::image_like(120, 64, 111);
        let mut rng = Rng::new(112);
        let idx = NnDescentIndex::build(&ds, Metric::L2Sq,
                                        NnDescentParams::default(), &mut rng);
        // measure edge recall vs exact 10-NN
        let mut hit = 0usize;
        let mut total = 0usize;
        for i in 0..30 {
            let truth = crate::baselines::exact::knn_point(
                &ds, i, 10, Metric::L2Sq, &mut Counter::new());
            let edges: std::collections::HashSet<u32> =
                idx.graph.neighbors[i].iter().copied().collect();
            for t in &truth.ids {
                total += 1;
                hit += edges.contains(t) as usize;
            }
        }
        let recall = hit as f64 / total as f64;
        assert!(recall > 0.8, "edge recall {recall}");
    }

    #[test]
    fn query_accuracy_and_sublinear_cost() {
        let ds = synthetic::image_like(300, 128, 113);
        let mut rng = Rng::new(114);
        let idx = NnDescentIndex::build(&ds, Metric::L2Sq,
                                        NnDescentParams::default(), &mut rng);
        let mut hits = 0usize;
        let mut c = Counter::new();
        let trials = 25;
        for q in 0..trials {
            let truth = crate::baselines::exact::knn_point(
                &ds, q, 1, Metric::L2Sq, &mut Counter::new());
            let got = idx.knn_query(ds.row(q), Some(q), 1, &mut rng, &mut c);
            hits += (got[0].0 == truth.ids[0]) as usize;
        }
        assert!(hits >= 21, "hits {hits}/{trials}");
        // fewer distance evals than brute force (the margin grows with n;
        // at n=300 the accuracy-tuned beam visits ~60% of points)
        let brute = trials as u64 * 299 * 128;
        assert!(c.get() < brute * 7 / 10,
                "cost {} vs brute {brute}", c.get());
    }

    #[test]
    fn degree_bounded() {
        let ds = synthetic::gaussian_iid(60, 16, 115);
        let mut rng = Rng::new(116);
        let idx = NnDescentIndex::build(
            &ds, Metric::L2Sq,
            NnDescentParams { degree: 5, ..Default::default() }, &mut rng);
        let (_, max, _) = idx.graph.degree_stats();
        assert!(max <= 5);
    }
}
