//! Baseline algorithms the paper compares against (Fig 3, 4a, 6):
//! exact computation, non-adaptive Monte Carlo, LSH (FALCONN stand-in),
//! NN-descent (kGraph stand-in), and ANNG (NGT stand-in).

pub mod exact;
pub mod graph;
pub mod graph_search;
pub mod lsh;
pub mod nndescent;
pub mod uniform;
