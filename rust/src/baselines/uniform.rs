//! Non-adaptive Monte Carlo baseline (Fig 1b / Fig 4a): estimate every θ_i
//! with the *same* number of coordinate samples and return the k smallest
//! estimates. This is the ablation that shows the adaptivity — not just
//! the estimator — is what makes BMO-NN work.

use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::Counter;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct UniformResult {
    pub ids: Vec<u32>,
    pub est_dists: Vec<f64>,
}

/// k-NN estimate with a fixed per-arm budget of `samples_per_arm`
/// coordinate draws (budget = n·samples_per_arm units).
pub fn knn_point(data: &DenseDataset, q: usize, k: usize, metric: Metric,
                 samples_per_arm: u64, rng: &mut Rng,
                 counter: &mut Counter) -> UniformResult {
    let d = data.d;
    let qrow = data.row(q);
    let mut est: Vec<(f64, u32)> = Vec::with_capacity(data.n - 1);
    // cap at exact computation — at m >= d you'd just compute exactly
    let m = samples_per_arm.min(d as u64);
    for i in 0..data.n {
        if i == q {
            continue;
        }
        let row = data.row(i);
        counter.add(m);
        let mut acc = 0f64;
        if m == d as u64 {
            acc = crate::data::dense::dist_slices(row, qrow, metric);
        } else {
            for _ in 0..m {
                let j = rng.below(d);
                acc += metric.coord(row[j], qrow[j]) as f64;
            }
            acc = acc / m as f64 * d as f64;
        }
        est.push((acc, i as u32));
    }
    est.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    est.truncate(k);
    UniformResult {
        ids: est.iter().map(|&(_, i)| i).collect(),
        est_dists: est.iter().map(|&(d, _)| d).collect(),
    }
}

/// Accuracy of the non-adaptive method at a total budget expressed as a
/// multiple of a reference (BMO) budget — the Fig-4a experiment helper.
pub fn accuracy_at_budget(
    data: &DenseDataset,
    queries: &[usize],
    k: usize,
    metric: Metric,
    total_budget_units: u64,
    rng: &mut Rng,
) -> f64 {
    let per_query = total_budget_units / queries.len() as u64;
    let per_arm = (per_query / (data.n as u64 - 1)).max(1);
    let mut correct = 0usize;
    for &q in queries {
        let mut c = Counter::new();
        let truth = crate::baselines::exact::knn_point(
            data, q, k, metric, &mut Counter::new());
        let got = knn_point(data, q, k, metric, per_arm, rng, &mut c);
        let a: std::collections::HashSet<_> = got.ids.iter().collect();
        let b: std::collections::HashSet<_> = truth.ids.iter().collect();
        if a == b {
            correct += 1;
        }
    }
    correct as f64 / queries.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn full_budget_equals_exact() {
        let ds = synthetic::gaussian_iid(20, 32, 71);
        let mut rng = Rng::new(72);
        let mut c = Counter::new();
        let got = knn_point(&ds, 0, 3, Metric::L2Sq, 32, &mut rng, &mut c);
        let want = crate::baselines::exact::knn_point(
            &ds, 0, 3, Metric::L2Sq, &mut Counter::new());
        assert_eq!(got.ids, want.ids);
        assert_eq!(c.get(), 19 * 32);
    }

    #[test]
    fn tiny_budget_is_usually_wrong_on_hard_data() {
        // near-tied arms: 1 sample per arm can't identify the NN
        let ds = synthetic::power_law_gaps(100, 512, 0.5, 4.0, 73);
        let mut rng = Rng::new(74);
        let mut wrong = 0;
        for trial in 0..20 {
            let mut c = Counter::new();
            let got =
                knn_point(&ds, 0, 1, Metric::L2Sq, 1, &mut rng, &mut c);
            let want = crate::baselines::exact::knn_point(
                &ds, 0, 1, Metric::L2Sq, &mut Counter::new());
            if got.ids != want.ids {
                wrong += 1;
            }
            let _ = trial;
        }
        assert!(wrong > 10, "only {wrong}/20 wrong with 1 sample/arm");
    }

    #[test]
    fn budget_accounting() {
        let ds = synthetic::gaussian_iid(10, 64, 75);
        let mut rng = Rng::new(76);
        let mut c = Counter::new();
        let _ = knn_point(&ds, 2, 1, Metric::L1, 7, &mut rng, &mut c);
        assert_eq!(c.get(), 9 * 7);
    }
}
