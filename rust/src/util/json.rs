//! Minimal JSON parser/serializer (no serde in the offline crate set).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), the
//! query-server wire protocol, and bench-result dumps. Supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn f32_array(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn usize_array(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "bad utf8".to_string())?;
                    out.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        // serialize then reparse
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{
            "artifacts": {
                "pull_rows_l2": {
                    "file": "pull_rows_l2.hlo.txt",
                    "inputs": [{"shape": [64, 1024], "dtype": "float32"}],
                    "meta": {"b": 64, "d": 1024, "t": 256, "metric": "l2"}
                }
            }
        }"#;
        let v = Json::parse(src).unwrap();
        let a = v.get("artifacts").unwrap().get("pull_rows_l2").unwrap();
        assert_eq!(a.get("meta").unwrap().get("d").unwrap().as_usize(),
                   Some(1024));
        let shape = a.get("inputs").unwrap().idx(0).unwrap().get("shape")
            .unwrap();
        assert_eq!(shape.idx(1).unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn float_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
