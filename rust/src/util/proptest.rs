//! Lightweight property-based testing (the offline crate set has no
//! `proptest`). `check` runs a property over many seeded random cases and,
//! on failure, re-reports the failing seed so the case is reproducible:
//!
//! ```ignore
//! proptest::check(256, |rng| {
//!     let n = 1 + rng.below(100);
//!     /* build inputs from rng, assert invariant, return Ok(()) or Err */
//!     Ok(())
//! });
//! ```
//!
//! Properties return `Result<(), String>` rather than panicking so the
//! harness can attach the seed to the message.

use super::rng::Rng;

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_seeded(0xB140_D17B, cases, &mut prop);
}

/// Same but with an explicit base seed (to reproduce a reported failure,
/// pass the printed seed with `cases = 1`).
pub fn check_seeded<F>(base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with util::proptest::check_seeded({seed:#x}, 1, ..)"
            );
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Equality assert producing `Result` (with Debug-printed operands) for
/// use inside properties.
#[macro_export]
macro_rules! prop_assert_eq {
    ($left:expr, $right:expr, $($fmt:tt)+) => {{
        let (l, r) = (&$left, &$right);
        if l != r {
            return Err(format!("{}: left {:?} != right {:?}",
                               format!($($fmt)+), l, r));
        }
    }};
    ($left:expr, $right:expr) => {{
        let (l, r) = (&$left, &$right);
        if l != r {
            return Err(format!("{} != {}: left {:?} != right {:?}",
                               stringify!($left), stringify!($right), l, r));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(64, |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn prop_assert_eq_passes_on_equal() {
        check(1, |_rng| {
            crate::prop_assert_eq!(2 + 2, 4);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "left 1")]
    fn prop_assert_eq_reports_both_sides() {
        check(1, |_rng| {
            crate::prop_assert_eq!(1, 2, "mismatch");
            Ok(())
        });
    }
}
