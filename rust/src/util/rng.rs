//! Deterministic, dependency-free PRNG (xoshiro256++ seeded by splitmix64).
//!
//! The offline build environment has no `rand` crate; this module provides
//! everything the library needs: uniform integers/floats, Gaussians
//! (Box–Muller with caching), ±1 signs, shuffles, and weighted choice.
//! All experiments are seeded so every figure regenerates bit-identically.

/// splitmix64 — used to expand a single u64 seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box–Muller
    gauss_cache: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent stream (for per-thread / per-query rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, bound). Lemire's nearly-divisionless method.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard Gaussian via Box–Muller (second value cached).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        // avoid u == 0
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_cache = Some(r * sin);
        r * cos
    }

    /// ±1 with equal probability.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates when k << n,
    /// rejection when tiny).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }

    /// Exponential with rate 1.
    pub fn exponential(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Standard Cauchy (for p-stable ℓ1 LSH).
    pub fn cauchy(&mut self) -> f64 {
        (std::f64::consts::PI * (self.f64() - 0.5)).tan()
    }

    /// Power-law sample: Δ with CDF F(Δ)=Δ^α on (0,1]  (Corollary 1).
    pub fn power_law(&mut self, alpha: f64) -> f64 {
        // inverse CDF: Δ = U^{1/α}
        self.f64().powf(1.0 / alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(6);
        for (n, k) in [(100, 5), (10, 10), (1000, 400)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn power_law_cdf() {
        // For alpha = 2, P(Δ <= 0.5) = 0.25.
        let mut r = Rng::new(8);
        let n = 100_000;
        let below = (0..n).filter(|_| r.power_law(2.0) <= 0.5).count();
        let frac = below as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
