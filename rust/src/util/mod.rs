//! Dependency-free utilities: PRNG, JSON, property-test harness.

pub mod json;
pub mod proptest;
pub mod rng;
