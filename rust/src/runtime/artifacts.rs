//! AOT artifact manifest: parses `artifacts/manifest.json` written by
//! `python/compile/aot.py` and validates shapes before anything is fed to
//! the PJRT runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Manifest errors are plain strings: this module must build in the
/// dependency-free offline configuration (no `anyhow`).
pub type Result<T> = std::result::Result<T, String>;

#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    /// free-form metadata from the python side (b, d, t, n, metric, ...)
    pub meta: BTreeMap<String, Json>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).and_then(|v| v.as_str())
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path).map_err(|e| {
            format!("reading {man_path:?} — run `make artifacts` first: {e}")
        })?;
        let json = Json::parse(&text)
            .map_err(|e| format!("parsing {man_path:?}: {e}"))?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| "manifest missing 'artifacts' object".to_string())?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            let file = spec
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("artifact {name}: missing file"))?;
            let file = dir.join(file);
            if !file.exists() {
                return Err(format!(
                    "artifact {name}: {file:?} does not exist"
                ));
            }
            let inputs = spec
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| format!("artifact {name}: missing inputs"))?
                .iter()
                .map(|inp| -> Result<InputSpec> {
                    let shape = inp
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| "bad input shape".to_string())?
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect();
                    let dtype = inp
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            let meta = spec
                .get("meta")
                .and_then(|m| m.as_obj())
                .cloned()
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, inputs, meta },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            format!("artifact '{name}' not in manifest (have: {:?})",
                    self.artifacts.keys().collect::<Vec<_>>())
        })
    }

    /// Default artifact directory: $BMONN_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("BMONN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let p = m.get("pull_rows_l2").unwrap();
        assert_eq!(p.inputs.len(), 3);
        assert_eq!(p.meta_str("metric"), Some("l2"));
        let b = p.meta_usize("b").unwrap();
        assert_eq!(p.inputs[0].shape[0], b);
        assert!(m.get("no_such_artifact").is_err());
    }

    #[test]
    fn rejects_missing_dir() {
        let r = Manifest::load(Path::new("/nonexistent/artifacts"));
        assert!(r.is_err());
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("bmonn_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":{"x":{"file":"x.hlo.txt",
                "inputs":[{"shape":[2,3],"dtype":"float32"}],
                "meta":{"b":2,"d":3,"metric":"l2"}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let x = m.get("x").unwrap();
        assert_eq!(x.inputs[0].shape, vec![2, 3]);
        assert_eq!(x.meta_usize("d"), Some(3));
        std::fs::remove_dir_all(&dir).ok();
    }
}
