//! Runtime layer: compute engines behind the coordinator's hot path.
//!
//! * [`kernels`] — the per-row hot-path kernels in three tiers
//!   (portable scalar, AVX2, NEON) behind runtime CPU-feature dispatch
//!   resolved once at engine construction;
//! * [`quant`] — the opt-in int8 quantized sampling tier: per-row
//!   affine shadow datasets for `partial_sums`/`pull_batch` waves, with
//!   the error bound the PAC accounting absorbs;
//! * [`native`] — optimized rust loops (wall-clock hot path, Fig 6),
//!   wave mechanics over the dispatched kernels;
//! * [`partition`] — the shared wave splitter: contiguous floor-boundary
//!   row shards, slot bookkeeping, scatter-merge. Both sharded backends
//!   below plan their waves here, so they provably split identically;
//! * [`sharded`] — multi-core wrapper fanning waves across contiguous
//!   row shards on a persistent worker pool, bit-identical to the
//!   wrapped engine run single-threaded;
//! * [`wire`] — the wave-tagged (v3, epoch-stamped) length-prefixed
//!   binary protocol `PullRequest` waves, replies and dataset-transfer
//!   streams travel over between machines;
//! * [`placement`] — replica placement for the ring: ordered replica
//!   lists per logical shard plus the per-endpoint backoff/blacklist
//!   state the failover path uses;
//! * [`fault`] — the deterministic fault-injection harness: a seeded,
//!   scripted TCP proxy ([`fault::FaultProxy`]) that sits between a
//!   ring client and a shard server and injects delays, mid-frame
//!   drops, corruption, blackholes and partitions on schedule, so the
//!   chaos tests exercise the failover machinery reproducibly;
//! * [`remote`] — multi-machine wrapper: a `shard-serve` TCP server per
//!   row shard (replicated at will, computing concurrent tagged waves
//!   per connection), the shared multiplexed [`remote::RingClient`]
//!   (one connection per shard per process, replies demultiplexed by
//!   wave tag, per-sub-wave replica failover) and the
//!   [`remote::RemoteEngine`] facade whose pipelined submit/complete
//!   waves stay bit-identical to a local `NativeEngine`;
//! * [`pjrt`] — the AOT JAX/Pallas artifacts, loaded from HLO text and
//!   executed via the PJRT C API (`xla` crate) with device-resident data;
//! * [`artifacts`] — the manifest that binds the two worlds together.
//!
//! Semantics of every engine are pinned to `ScalarEngine`
//! (coordinator::arms) by parity tests.

pub mod artifacts;
pub mod fault;
pub mod kernels;
pub mod native;
pub mod partition;
pub mod placement;
pub mod quant;
pub mod remote;
pub mod sharded;
pub mod wire;

use crate::config::EngineKind;
use crate::coordinator::arms::{PullEngine, ScalarEngine};
use kernels::KernelChoice;
use std::time::Duration;

/// Build the configured host-side pull engine.
///
/// * `remote` non-empty (`[engine] remote` / `--remote`, one spec per
///   shard, replicas `|`-separated within a spec): connect a
///   [`remote::RemoteEngine`] to that shard-server ring — the ring's
///   servers compute with the native engine, and a coordinator box
///   built this way composes unchanged with the batch drivers and the
///   query server's worker pool. Mutually exclusive with `shards` (the
///   ring is already sharded across its endpoints). `degraded`
///   (`[engine] degraded` / `--degraded`) opts the ring into
///   coverage-annotated answers over surviving rows while a shard has
///   no live replica, instead of hard query errors.
/// * otherwise: the local scalar/native engine, wrapped in
///   [`sharded::ShardedEngine`] when `shards > 1` (`[engine] shards` /
///   `--shards S`). `degraded` is meaningless without a ring and is
///   rejected.
///
/// The PJRT engine is constructed separately by its callers (it needs an
/// artifact dir + metric and aligns `round_pulls` to the artifact
/// shape), so requesting it here is an error.
///
/// `kernel` (`[engine] kernel` / `--kernel`) forces the native engine's
/// per-row kernel tier; `quantized` (`[engine] quantized` /
/// `--quantized`) routes its sampled waves through the int8 shadow
/// tier. Both tune the process doing the computing, so with a remote
/// ring `kernel` must be set on the `shard-serve` side, and `quantized`
/// is local-only (the wire protocol carries no bias bound for the
/// coordinator's PAC accounting to absorb) — requesting either here
/// alongside `--remote` is rejected rather than silently ignored, and
/// both are meaningless for the f64 `ScalarEngine`.
///
/// `sparse` marks the caller's dataset as sparse (`.bms` inputs): the
/// wire protocol ships dense f32 row blocks only, so `sparse` combined
/// with `--remote` is a validated error instead of an undefined path —
/// sparse queries stay on the local CSR engine.
///
/// `io_timeout` (`[engine] io_timeout_ms` / `--io-timeout-ms`) bounds
/// the ring client's connects, writes and per-wave reply waits; local
/// engines have no I/O and ignore it.
#[allow(clippy::too_many_arguments)]
pub fn build_host_engine(kind: EngineKind, shards: usize,
                         remote: &[String], degraded: bool,
                         kernel: KernelChoice, quantized: bool,
                         sparse: bool, io_timeout: Option<Duration>)
                         -> Result<Box<dyn PullEngine + Send>, String> {
    let shards = shards.max(1);
    if !remote.is_empty() {
        if sparse {
            return Err("--remote serves dense datasets only: the wire \
                        protocol ships dense f32 row blocks, and shard \
                        servers have no CSR engine — drop --remote to \
                        query sparse data locally"
                .into());
        }
        if shards > 1 {
            return Err("--shards and --remote are mutually exclusive: a \
                        remote ring is already sharded across its \
                        endpoints"
                .into());
        }
        if kind != EngineKind::Native {
            return Err("--remote always computes with the native engine \
                        (that is what shard servers run); combine it \
                        with --engine native or drop the engine flag"
                .into());
        }
        if kernel != KernelChoice::Auto {
            return Err("--kernel selects the tier of the process doing \
                        the computing: pass it to shard-serve, not to a \
                        --remote coordinator"
                .into());
        }
        if quantized {
            return Err("--quantized is a local-engine feature: the \
                        coordinator must widen confidence intervals by \
                        the engine's quantization error bound, and the \
                        wire protocol carries no such bound — drop \
                        --remote to use the quantized tier"
                .into());
        }
        let map = placement::PlacementMap::parse(remote)?;
        let timeout =
            io_timeout.or(Some(remote::DEFAULT_IO_TIMEOUT));
        return Ok(Box::new(remote::RemoteEngine::connect_opts(
            &map,
            remote::RemoteOptions { degraded,
                                    timeout,
                                    ..remote::RemoteOptions::default() },
        )?));
    }
    if degraded {
        return Err("--degraded applies to --remote rings: local engines \
                    have no shards to lose"
            .into());
    }
    if kind == EngineKind::Scalar
        && (kernel != KernelChoice::Auto || quantized)
    {
        return Err("--kernel/--quantized tune the native engine; the \
                    scalar engine is the f64 semantic reference and has \
                    exactly one implementation"
            .into());
    }
    Ok(match kind {
        EngineKind::Scalar if shards == 1 => Box::new(ScalarEngine),
        EngineKind::Scalar => {
            Box::new(sharded::ShardedEngine::new(ScalarEngine, shards))
        }
        EngineKind::Native if shards == 1 => {
            Box::new(native::NativeEngine::with_options(kernel,
                                                        quantized)?)
        }
        EngineKind::Native => Box::new(sharded::ShardedEngine::new(
            native::NativeEngine::with_options(kernel, quantized)?,
            shards,
        )),
        EngineKind::Pjrt => {
            return Err("pjrt engine is built from its artifact dir by the \
                        caller; --shards applies to host engines \
                        (native|scalar)"
                .into())
        }
    })
}

// The real PJRT runtime needs the `xla` bindings and `anyhow`, neither of
// which is available in the offline crate set. The default build compiles
// an API-compatible stub whose constructors return errors, so every caller
// (CLI `selftest`, integration tests, `serve_queries --pjrt`) still builds
// and degrades gracefully at runtime.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
