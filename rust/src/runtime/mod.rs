//! Runtime layer: compute engines behind the coordinator's hot path.
//!
//! * [`native`] — optimized rust loops (wall-clock hot path, Fig 6);
//! * [`pjrt`] — the AOT JAX/Pallas artifacts, loaded from HLO text and
//!   executed via the PJRT C API (`xla` crate) with device-resident data;
//! * [`artifacts`] — the manifest that binds the two worlds together.
//!
//! Semantics of every engine are pinned to `ScalarEngine`
//! (coordinator::arms) by parity tests.

pub mod artifacts;
pub mod native;
pub mod pjrt;
