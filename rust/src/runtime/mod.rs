//! Runtime layer: compute engines behind the coordinator's hot path.
//!
//! * [`native`] — optimized rust loops (wall-clock hot path, Fig 6);
//! * [`pjrt`] — the AOT JAX/Pallas artifacts, loaded from HLO text and
//!   executed via the PJRT C API (`xla` crate) with device-resident data;
//! * [`artifacts`] — the manifest that binds the two worlds together.
//!
//! Semantics of every engine are pinned to `ScalarEngine`
//! (coordinator::arms) by parity tests.

pub mod artifacts;
pub mod native;

// The real PJRT runtime needs the `xla` bindings and `anyhow`, neither of
// which is available in the offline crate set. The default build compiles
// an API-compatible stub whose constructors return errors, so every caller
// (CLI `selftest`, integration tests, `serve_queries --pjrt`) still builds
// and degrades gracefully at runtime.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
