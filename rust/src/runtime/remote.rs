//! Network-distributed pull execution: fan engine waves over a ring of
//! TCP **shard servers**, each owning a contiguous row range of the
//! dataset.
//!
//! Two halves:
//!
//! * [`ShardServer`] — the `bmonn shard-serve` backend. It holds rows
//!   `[row_start, row_end)` of the global dataset and answers
//!   `partial_sums` / `exact_dists` / `pull_batch` waves over the
//!   length-prefixed binary protocol in [`crate::runtime::wire`],
//!   computing with a per-connection `NativeEngine`. Rows travel as
//!   global ids and are rebased locally; anything outside the owned
//!   range is answered with a wire `Error`, never a crash.
//! * [`RemoteEngine`] — a [`PullEngine`] holding one persistent
//!   connection per shard endpoint. Every wave is split with the same
//!   [`crate::runtime::partition::WavePartition`] the in-process
//!   [`crate::runtime::sharded::ShardedEngine`] uses (one splitter,
//!   shared code), sub-waves fan out concurrently on scoped threads, and
//!   replies scatter back by slot — so remote output is **bitwise
//!   identical** to a single-threaded `NativeEngine` for any ring size
//!   (`tests/remote_parity.rs` pins this case-for-case against
//!   `tests/sharded_parity.rs`).
//!
//! **Ring contract.** Endpoint `i` of `S` must serve exactly
//! `shard_range(i, n, S)`; [`RemoteEngine::connect`] verifies this
//! against each server's handshake and refuses a ring that tiles the
//! dataset any other way. The coordinator's dataset must match the
//! ring's (n, d) — a mismatched wave panics with a clear message.
//!
//! **Fault model.** A shard death mid-wave surfaces as a panic from the
//! wave call (reads carry a timeout, so a hung peer cannot strand the
//! caller). The query server's worker loop catches that panic, answers
//! the affected queries with error responses, and rebuilds — i.e.
//! reconnects — the engine (`coordinator::server`), extending the
//! in-process worker-survival guarantee across the network boundary
//! (`tests/remote_fault.rs`).

use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::arms::{PullEngine, PullRequest};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::native::NativeEngine;
use crate::runtime::partition::{shard_range, ShardWave, WavePartition};
use crate::runtime::wire::{self, Message, WireRequest};

/// Default per-connection read/write timeout: long enough for a big wave
/// to compute server-side, short enough that a wedged peer can never
/// strand a coordinator worker forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// shard server
// ---------------------------------------------------------------------

struct ShardShared {
    /// this shard's rows only (global rows `[row_start, row_start + n)`)
    local: DenseDataset,
    n_total: usize,
    row_start: usize,
    shutdown: AtomicBool,
    /// live connections (by id), shut down on stop so blocked I/O
    /// unblocks; each entry is removed when its handler thread exits, so
    /// a long-running server does not leak one fd per past connection
    conns: Mutex<Vec<(u64, TcpStream)>>,
}

/// A running shard server (see module docs). Stops on drop; a wire
/// `Shutdown` message also stops it (that is how a `shard-serve` CLI
/// process is told to exit remotely).
pub struct ShardServer {
    pub addr: SocketAddr,
    shared: Arc<ShardShared>,
    accept_handle: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Serve `local` (the rows `[row_start, row_start + local.n)` of a
    /// global `n_total`-row dataset) on `addr` (`"host:0"` picks an
    /// ephemeral port; see `self.addr`).
    pub fn start(addr: &str, local: DenseDataset, n_total: usize,
                 row_start: usize) -> io::Result<ShardServer> {
        assert!(row_start + local.n <= n_total,
                "shard rows [{row_start}, {}) exceed n_total={n_total}",
                row_start + local.n);
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(ShardShared {
            local,
            n_total,
            row_start,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bmonn-shard-serve".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .expect("spawn shard-serve accept thread");
        Ok(ShardServer { addr, shared, accept_handle: Some(accept_handle) })
    }

    /// Slice shard `shard` of `n_shards` out of `data` (the same
    /// floor-boundary partition `RemoteEngine` splits waves with) and
    /// serve it.
    pub fn start_shard_of(addr: &str, data: &DenseDataset, shard: usize,
                          n_shards: usize) -> io::Result<ShardServer> {
        let (a, b) = shard_range(shard, data.n, n_shards);
        let mut rows = Vec::with_capacity((b - a) * data.d);
        for r in a..b {
            rows.extend_from_slice(data.row(r));
        }
        Self::start(addr, DenseDataset::new(b - a, data.d, rows), data.n, a)
    }

    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// True once a wire `Shutdown` was received (or `stop` was called) —
    /// the `shard-serve` CLI polls this to know when to exit.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop serving: kills live connections (blocked peer reads see EOF,
    /// like a process death would produce) and joins the accept thread.
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for (_, s) in self.shared.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start one in-process shard server per shard of `data` on loopback
/// ephemeral ports — the zero-infrastructure ring used by the parity
/// tests and the `bench pull` distributed rung.
pub fn spawn_loopback_ring(data: &DenseDataset, n_shards: usize)
                           -> Result<(Vec<ShardServer>, Vec<String>), String> {
    let mut servers = Vec::with_capacity(n_shards);
    let mut endpoints = Vec::with_capacity(n_shards);
    for i in 0..n_shards {
        let srv = ShardServer::start_shard_of("127.0.0.1:0", data, i,
                                              n_shards)
            .map_err(|e| format!("starting loopback shard {i}: {e}"))?;
        endpoints.push(srv.endpoint());
        servers.push(srv);
    }
    Ok((servers, endpoints))
}

fn accept_loop(listener: TcpListener, shared: Arc<ShardShared>) {
    let mut handles = Vec::new();
    let mut next_id = 0u64;
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let id = next_id;
                next_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().push((id, clone));
                }
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = serve_conn(stream, sh.clone());
                    // deregister so past connections don't pin fds
                    sh.conns.lock().unwrap().retain(|(c, _)| *c != id);
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    // a wire Shutdown set the flag without going through stop(): kill
    // the remaining connections so their blocked reads return, then reap
    for (_, s) in shared.conns.lock().unwrap().iter() {
        let _ = s.shutdown(Shutdown::Both);
    }
    for h in handles {
        let _ = h.join();
    }
}

/// One connection: framed request/reply until disconnect or `Shutdown`.
/// A panic in the compute path answers with a wire `Error` and a fresh
/// engine instead of dropping the connection.
fn serve_conn(mut stream: TcpStream, shared: Arc<ShardShared>)
              -> io::Result<()> {
    stream.set_nodelay(true)?;
    let mut engine = NativeEngine::default();
    let mut inbuf = Vec::new();
    let mut outbuf = Vec::new();
    let mut sums = Vec::new();
    let mut sqs = Vec::new();
    loop {
        if wire::read_frame(&mut stream, &mut inbuf).is_err() {
            return Ok(()); // disconnect, kill, or corrupt framing
        }
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_frame(&shared, &mut engine, &inbuf, &mut outbuf,
                             &mut sums, &mut sqs)
            }));
        let quit = match outcome {
            Ok(q) => q,
            Err(_) => {
                engine = NativeEngine::default();
                wire::encode_error(&mut outbuf,
                                   "internal error: shard compute panicked");
                false
            }
        };
        wire::write_frame(&mut stream, &outbuf)?;
        if quit {
            return Ok(());
        }
    }
}

/// Decode + dispatch one request; returns true when the connection (and
/// server) should wind down.
fn handle_frame(sh: &ShardShared, engine: &mut NativeEngine, payload: &[u8],
                out: &mut Vec<u8>, sums: &mut Vec<f64>, sqs: &mut Vec<f64>)
                -> bool {
    let msg = match Message::decode(payload) {
        Err(e) => {
            wire::encode_error(out, &format!("bad frame: {e}"));
            return false;
        }
        Ok(m) => m,
    };
    match msg {
        Message::Hello => wire::encode_hello_ack(
            out,
            sh.n_total as u64,
            sh.local.d as u64,
            sh.row_start as u64,
            (sh.row_start + sh.local.n) as u64,
        ),
        Message::Shutdown => {
            sh.shutdown.store(true, Ordering::SeqCst);
            wire::encode_ack(out);
            return true;
        }
        Message::PartialSums { metric, query, rows, coord_ids } => {
            match validate_and_rebase(sh, &query, &rows, Some(&coord_ids)) {
                Err(e) => wire::encode_error(out, &e),
                Ok(local_rows) => {
                    engine.partial_sums(&sh.local, &query, &local_rows,
                                        &coord_ids, metric, sums, sqs);
                    wire::encode_sums(out, sums, sqs);
                }
            }
        }
        Message::ExactDists { metric, query, rows } => {
            match validate_and_rebase(sh, &query, &rows, None) {
                Err(e) => wire::encode_error(out, &e),
                Ok(local_rows) => {
                    engine.exact_dists(&sh.local, &query, &local_rows,
                                       metric, sums);
                    wire::encode_dists(out, sums);
                }
            }
        }
        Message::PullBatch { metric, reqs } => {
            match batch_compute(sh, engine, metric, &reqs, sums, sqs) {
                Err(e) => wire::encode_error(out, &e),
                Ok(()) => wire::encode_sums(out, sums, sqs),
            }
        }
        other => wire::encode_error(
            out,
            &format!("unexpected {} request", other.kind()),
        ),
    }
    false
}

/// Check dims/coords and map global row ids onto this shard's local
/// `[0, local.n)` range.
fn validate_and_rebase(sh: &ShardShared, query: &[f32], rows: &[u32],
                       coord_ids: Option<&[u32]>)
                       -> Result<Vec<u32>, String> {
    if query.len() != sh.local.d {
        return Err(format!("query dim {} != dataset dim {}", query.len(),
                           sh.local.d));
    }
    if let Some(cs) = coord_ids {
        if let Some(&j) = cs.iter().find(|&&j| j as usize >= sh.local.d) {
            return Err(format!("coordinate {j} out of range (d={})",
                               sh.local.d));
        }
    }
    let (a, b) = (sh.row_start, sh.row_start + sh.local.n);
    let mut local = Vec::with_capacity(rows.len());
    for &r in rows {
        let r = r as usize;
        if r < a || r >= b {
            return Err(format!(
                "row {r} outside this shard's range [{a}, {b})"));
        }
        local.push((r - a) as u32);
    }
    Ok(local)
}

/// Rebase and resolve a `PullBatch` wave with one engine pass; outputs
/// land in `sums`/`sqs` concatenated request-major, exactly as
/// [`PullEngine::pull_batch`] specifies.
fn batch_compute(sh: &ShardShared, engine: &mut NativeEngine,
                 metric: Metric, reqs: &[WireRequest], sums: &mut Vec<f64>,
                 sqs: &mut Vec<f64>) -> Result<(), String> {
    let mut flat: Vec<u32> = Vec::new();
    let mut bounds = Vec::with_capacity(reqs.len());
    for r in reqs {
        let start = flat.len();
        let local = validate_and_rebase(sh, &r.query, &r.rows,
                                        Some(&r.coord_ids))?;
        flat.extend_from_slice(&local);
        bounds.push((start, flat.len()));
    }
    let views: Vec<PullRequest> = reqs
        .iter()
        .zip(&bounds)
        .map(|(r, &(a, b))| PullRequest {
            query: &r.query,
            rows: &flat[a..b],
            coord_ids: &r.coord_ids,
        })
        .collect();
    engine.pull_batch(&sh.local, &views, metric, sums, sqs);
    Ok(())
}

// ---------------------------------------------------------------------
// remote engine (client)
// ---------------------------------------------------------------------

/// One persistent shard connection plus its reusable frame buffers.
struct RemoteShard {
    endpoint: String,
    stream: TcpStream,
    sendbuf: Vec<u8>,
    recvbuf: Vec<u8>,
}

type ShardReply = Result<(Vec<f64>, Vec<f64>), String>;

impl RemoteShard {
    fn round_trip(&mut self) -> Result<Message, String> {
        wire::write_frame(&mut self.stream, &self.sendbuf)
            .map_err(|e| format!("shard {}: send failed: {e}",
                                 self.endpoint))?;
        wire::read_frame(&mut self.stream, &mut self.recvbuf)
            .map_err(|e| format!("shard {}: recv failed: {e}",
                                 self.endpoint))?;
        Message::decode(&self.recvbuf)
            .map_err(|e| format!("shard {}: bad reply: {e}", self.endpoint))
    }

    fn expect_sums(&mut self, expected: usize) -> ShardReply {
        match self.round_trip()? {
            Message::Sums { sum, sq } => {
                if sum.len() != expected {
                    return Err(format!(
                        "shard {}: {} results for {expected} requested rows",
                        self.endpoint,
                        sum.len()
                    ));
                }
                Ok((sum, sq))
            }
            Message::Error { msg } => {
                Err(format!("shard {}: {msg}", self.endpoint))
            }
            other => Err(format!("shard {}: unexpected {} reply",
                                 self.endpoint, other.kind())),
        }
    }

    fn expect_dists(&mut self, expected: usize) -> Result<Vec<f64>, String> {
        match self.round_trip()? {
            Message::Dists { vals } => {
                if vals.len() != expected {
                    return Err(format!(
                        "shard {}: {} results for {expected} requested rows",
                        self.endpoint,
                        vals.len()
                    ));
                }
                Ok(vals)
            }
            Message::Error { msg } => {
                Err(format!("shard {}: {msg}", self.endpoint))
            }
            other => Err(format!("shard {}: unexpected {} reply",
                                 self.endpoint, other.kind())),
        }
    }
}

/// Run `per_shard` for every shard that owns part of the current wave.
/// With more than one live sub-wave the round trips overlap on scoped
/// threads; a single live sub-wave skips the spawn and runs inline.
fn fan_out<F>(conns: &mut [RemoteShard], part: &WavePartition,
              per_shard: F) -> Vec<ShardReply>
where
    F: Fn(&mut RemoteShard, &ShardWave) -> ShardReply + Sync,
{
    let live = (0..conns.len())
        .filter(|&i| !part.wave(i).rows.is_empty())
        .count();
    if live <= 1 {
        return conns
            .iter_mut()
            .enumerate()
            .map(|(i, c)| {
                let w = part.wave(i);
                if w.rows.is_empty() {
                    Ok((Vec::new(), Vec::new()))
                } else {
                    per_shard(c, w)
                }
            })
            .collect();
    }
    let n = conns.len();
    std::thread::scope(|sc| {
        let per_shard = &per_shard;
        // spawn only for shards that actually own work — an 8-endpoint
        // ring serving a 2-shard wave pays 2 spawns, not 8
        let handles: Vec<_> = conns
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| !part.wave(*i).rows.is_empty())
            .map(|(i, c)| {
                let w = part.wave(i);
                (i, sc.spawn(move || per_shard(c, w)))
            })
            .collect();
        let mut results: Vec<ShardReply> =
            (0..n).map(|_| Ok((Vec::new(), Vec::new()))).collect();
        for (i, h) in handles {
            results[i] = h.join().unwrap_or_else(|_| {
                Err("remote shard I/O thread panicked".into())
            });
        }
        results
    })
}

/// Dial one endpoint, honoring `timeout` during the connect phase too —
/// a blackholed host (no RST) must not strand the caller for the OS SYN
/// retry window.
fn connect_endpoint(ep: &str, timeout: Option<Duration>)
                    -> io::Result<TcpStream> {
    let Some(t) = timeout else {
        return TcpStream::connect(ep);
    };
    let addrs: Vec<SocketAddr> = ep.to_socket_addrs()?.collect();
    let mut last_err = None;
    for addr in addrs {
        match TcpStream::connect_timeout(&addr, t) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidInput,
                       "endpoint resolved to no addresses")
    }))
}

/// Networked [`PullEngine`] over a ring of shard servers — see the
/// module docs for the ring contract, determinism and fault model.
pub struct RemoteEngine {
    conns: Vec<RemoteShard>,
    n_total: usize,
    d: usize,
    partition: WavePartition,
}

impl RemoteEngine {
    /// Connect to every endpoint, handshake, and verify the ring tiles
    /// the dataset with the canonical floor-boundary partition.
    pub fn connect(endpoints: &[String]) -> Result<RemoteEngine, String> {
        Self::connect_with_timeout(endpoints, Some(DEFAULT_IO_TIMEOUT))
    }

    /// [`RemoteEngine::connect`] with an explicit per-connection I/O
    /// timeout (`None` = block forever; tests use short timeouts).
    pub fn connect_with_timeout(endpoints: &[String],
                                timeout: Option<Duration>)
                                -> Result<RemoteEngine, String> {
        if endpoints.is_empty() {
            return Err("remote engine needs at least one shard endpoint"
                .into());
        }
        let s = endpoints.len();
        let mut conns = Vec::with_capacity(s);
        let mut shape: Option<(usize, usize)> = None;
        for (i, ep) in endpoints.iter().enumerate() {
            let stream = connect_endpoint(ep, timeout)
                .map_err(|e| format!("connecting shard {i} ({ep}): {e}"))?;
            stream.set_nodelay(true).map_err(|e| e.to_string())?;
            stream.set_read_timeout(timeout).map_err(|e| e.to_string())?;
            stream.set_write_timeout(timeout).map_err(|e| e.to_string())?;
            let mut shard = RemoteShard {
                endpoint: ep.clone(),
                stream,
                sendbuf: Vec::new(),
                recvbuf: Vec::new(),
            };
            wire::encode_hello(&mut shard.sendbuf);
            let (n, d, a, b) = match shard.round_trip()? {
                Message::HelloAck { n_total, d, row_start, row_end } => {
                    (n_total as usize, d as usize, row_start as usize,
                     row_end as usize)
                }
                other => {
                    return Err(format!(
                        "shard {i} ({ep}): unexpected {} handshake reply",
                        other.kind()))
                }
            };
            match shape {
                None => shape = Some((n, d)),
                Some((n0, d0)) if (n0, d0) != (n, d) => {
                    return Err(format!(
                        "shard {i} ({ep}) serves n={n} d={d} but shard 0 \
                         serves n={n0} d={d0} — the ring must load one \
                         dataset"))
                }
                Some(_) => {}
            }
            let (want_a, want_b) = shard_range(i, n, s);
            if (a, b) != (want_a, want_b) {
                return Err(format!(
                    "shard {i} ({ep}) serves rows [{a}, {b}) but the \
                     {s}-way partition of n={n} assigns [{want_a}, \
                     {want_b}) — start it as shard {i} of {s}"));
            }
            conns.push(shard);
        }
        let (n_total, d) = shape.unwrap();
        Ok(RemoteEngine {
            conns,
            n_total,
            d,
            partition: WavePartition::new(s),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.conns.len()
    }

    /// The ring's global dataset shape, learned at handshake.
    pub fn shape(&self) -> (usize, usize) {
        (self.n_total, self.d)
    }

    fn check_dataset(&self, data: &DenseDataset) {
        assert!(
            data.n == self.n_total && data.d == self.d,
            "remote ring serves n={} d={} but this wave's dataset is n={} \
             d={} — every shard server must load the same dataset as the \
             coordinator",
            self.n_total, self.d, data.n, data.d
        );
    }

    fn scatter2(&self, results: Vec<ShardReply>, out_sum: &mut [f64],
                out_sq: &mut [f64]) {
        for (i, res) in results.into_iter().enumerate() {
            match res {
                Ok((sum, sq)) => {
                    let w = self.partition.wave(i);
                    w.scatter(&sum, out_sum);
                    w.scatter(&sq, out_sq);
                }
                Err(e) => panic!("remote pull wave failed: {e}"),
            }
        }
    }
}

impl PullEngine for RemoteEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(rows.len(), 0.0);
        out_sq.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let results = fan_out(&mut self.conns, &self.partition,
                              |shard, wave| {
            wire::encode_partial_sums(&mut shard.sendbuf, metric, query,
                                      &wave.rows, coord_ids);
            shard.expect_sums(wave.rows.len())
        });
        self.scatter2(results, out_sum, out_sq);
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        out.clear();
        out.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let results = fan_out(&mut self.conns, &self.partition,
                              |shard, wave| {
            wire::encode_exact_dists(&mut shard.sendbuf, metric, query,
                                     &wave.rows);
            shard.expect_dists(wave.rows.len()).map(|v| (v, Vec::new()))
        });
        for (i, res) in results.into_iter().enumerate() {
            match res {
                Ok((vals, _)) => self.partition.wave(i).scatter(&vals, out),
                Err(e) => panic!("remote exact wave failed: {e}"),
            }
        }
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        let total = self.partition.split_batch(data.n, reqs);
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        let results = fan_out(&mut self.conns, &self.partition,
                              |shard, wave| {
            let sub: Vec<PullRequest> = wave.subrequests(reqs).collect();
            wire::encode_pull_batch(&mut shard.sendbuf, metric, &sub);
            shard.expect_sums(wave.rows.len())
        });
        self.scatter2(results, out_sum, out_sq);
    }

    fn name(&self) -> &'static str {
        "remote"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn raw_round_trip(stream: &mut TcpStream, payload: &[u8]) -> Message {
        wire::write_frame(stream, payload).unwrap();
        let mut buf = Vec::new();
        wire::read_frame(stream, &mut buf).unwrap();
        Message::decode(&buf).unwrap()
    }

    #[test]
    fn handshake_reports_shape_and_shutdown_stops_the_server() {
        let ds = synthetic::gaussian_iid(10, 8, 1);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 2)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf);
        match raw_round_trip(&mut stream, &buf) {
            Message::HelloAck { n_total, d, row_start, row_end } => {
                assert_eq!((n_total, d), (10, 8));
                assert_eq!((row_start, row_end), (5, 10));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_shutdown(&mut buf);
        assert_eq!(raw_round_trip(&mut stream, &buf), Message::Ack);
        assert!(srv.shutdown_requested());
    }

    #[test]
    fn server_answers_errors_for_invalid_requests() {
        let ds = synthetic::gaussian_iid(12, 6, 2);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 3)
            .unwrap(); // owns rows [0, 4)
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let q = vec![0.0f32; 6];
        let mut buf = Vec::new();
        // out-of-range row
        wire::encode_partial_sums(&mut buf, Metric::L2Sq, &q, &[7], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("row 7")),
            other => panic!("unexpected {}", other.kind()),
        }
        // wrong query dim
        wire::encode_exact_dists(&mut buf, Metric::L1, &[1.0], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("dim")),
            other => panic!("unexpected {}", other.kind()),
        }
        // coordinate out of range
        wire::encode_partial_sums(&mut buf, Metric::L1, &q, &[1], &[99]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("coordinate")),
            other => panic!("unexpected {}", other.kind()),
        }
        // garbage payload: error reply, connection stays usable
        match raw_round_trip(&mut stream, &[42, 1, 2]) {
            Message::Error { msg } => assert!(msg.contains("bad frame")),
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_partial_sums(&mut buf, Metric::L1, &q, &[1], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Sums { sum, sq } => {
                assert_eq!(sum.len(), 1);
                assert_eq!(sq.len(), 1);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn connect_rejects_a_ring_that_does_not_tile_the_dataset() {
        let ds = synthetic::gaussian_iid(9, 4, 3);
        // both servers claim shard 0 of 2 — the second endpoint's range
        // does not match the partition's assignment for index 1
        let s0 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let s1 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s1.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("partition"), "got: {err}");
        // mismatched dataset shapes are rejected too
        let other = synthetic::gaussian_iid(7, 4, 4);
        let s2 = ShardServer::start_shard_of("127.0.0.1:0", &other, 1, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s2.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("one dataset") || err.contains("partition"),
                "got: {err}");
    }

    #[test]
    fn wave_against_a_mismatched_dataset_panics_with_context() {
        let ds = synthetic::gaussian_iid(8, 4, 5);
        let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let mut eng = RemoteEngine::connect(&eps).unwrap();
        assert_eq!(eng.shape(), (8, 4));
        assert_eq!(eng.n_shards(), 2);
        assert_eq!(eng.name(), "remote");
        let wrong = synthetic::gaussian_iid(9, 4, 6);
        let q = wrong.row_vec(0);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                eng.partial_sums(&wrong, &q, &[0], &[0], Metric::L2Sq,
                                 &mut s, &mut sq);
            }))
            .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("same dataset"), "got: {msg}");
    }
}
