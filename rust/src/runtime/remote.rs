//! Network-distributed pull execution: fan engine waves over a
//! **replicated ring** of TCP shard servers, each owning a contiguous
//! row range of the dataset, through a **multiplexed, pipelined** ring
//! client — one connection per shard per process, many concurrent
//! tagged waves in flight on each connection.
//!
//! Three pieces:
//!
//! * [`ShardServer`] — the `bmonn shard-serve` backend. It holds rows
//!   `[row_start, row_end)` of the global dataset and answers
//!   `partial_sums` / `exact_dists` / `pull_batch` waves over the
//!   wave-tagged binary protocol in [`crate::runtime::wire`]. Each
//!   connection's compute waves run on their own threads (bounded by
//!   [`MAX_CONN_WAVES`]), so several tagged waves of one connection
//!   compute **concurrently** and replies may leave in any order — the
//!   tag, not arrival order, routes them. Rows travel as global ids and
//!   are rebased locally; anything invalid is answered with a wire
//!   `Error`, never a crash. A `Stats` frame (the health op) reports
//!   the server's shard identity, row range, dataset fingerprint and
//!   live-connection count without touching the compute path. A v1
//!   (untagged) client is answered with a clean v1-framed version error
//!   and disconnected — never a hang or a panic.
//! * [`RingClient`] — the shared, multiplexed client: **one connection
//!   set per process**, safely shared by every thread (`Arc`). Each
//!   logical shard has an ordered replica list
//!   ([`crate::runtime::placement::PlacementMap`]), one live connection
//!   at a time, a writer that interleaves sub-waves from many callers,
//!   and a **demultiplexing reader thread** that routes replies by
//!   `wave_id` to per-wave completion slots. Independent callers'
//!   waves genuinely overlap on the wire (the per-connection in-flight
//!   high-water mark is exported — `bench pull`'s multiplex rung
//!   asserts ≥ 2).
//! * [`RemoteEngine`] — a [`PullEngine`] over a shared [`RingClient`].
//!   Every wave is split with the same
//!   [`crate::runtime::partition::WavePartition`] the in-process
//!   [`crate::runtime::sharded::ShardedEngine`] uses (one splitter,
//!   shared code), and the split `submit_* -> WaveTicket` /
//!   `complete_*` API is genuinely pipelined: sub-waves are on the
//!   wire when submit returns, several waves may be in flight from one
//!   caller, and completion order is free. The blocking calls are
//!   implemented as submit + complete, so remote output is **bitwise
//!   identical** to a single-threaded `NativeEngine` for any ring size
//!   and any interleaving (`tests/remote_parity.rs`,
//!   `tests/multiplex.rs`).
//!
//! **Ring contract.** Every replica of logical shard `i` of `S` must
//! serve exactly `shard_range(i, n, S)` of the same dataset. The
//! handshake proves it: shape and row range are validated against the
//! canonical partition, the protocol version must match, and the
//! replica's **dataset fingerprint**
//! ([`crate::runtime::wire::dataset_fingerprint`]) must agree with the
//! fingerprint its shard-mates established — a replica serving
//! divergent bytes is refused (and `bmonn ring-stats` reports it with a
//! nonzero exit).
//!
//! **Failover.** Failover is **per sub-wave**: an I/O error, corrupt
//! reply or timeout kills the connection it happened on, blacklists
//! that replica (exponential backoff,
//! [`crate::runtime::placement::RetryPolicy`]) and fails **only the
//! sub-waves that were in flight on it** over to the shard's next live
//! replica — each re-issues its identical staged payload, and each
//! endpoint is tried at most once per sub-wave, so retries are bounded.
//! A wire `Error` reply fails its one sub-wave over *without*
//! blacklisting (the connection is healthy — only that request failed
//! server-side). Because every replica computes the same jobs with the
//! same kernel, a failed-over wave is bitwise identical to a healthy
//! one: killing any single endpoint of a replicated ring mid-stream
//! yields no query errors at all (`tests/remote_fault.rs`). A
//! blacklisted endpoint heals the moment a reconnect + handshake
//! succeeds after its backoff window.
//!
//! **Degraded mode.** With every replica of some shard dead, a wave
//! touching that shard's rows still panics (promptly — waits carry a
//! timeout) and the query server answers errors, exactly as in the
//! unreplicated ring. Opting in via `[engine] degraded = true` /
//! `--degraded` changes that: [`RingClient::coverage`] then reports
//! the surviving row ranges, and the k-NN drivers
//! (`coordinator::knn`) answer **exact** top-k over the surviving rows
//! only, threading a `coverage` annotation through
//! [`crate::coordinator::knn::KnnResult`] and the query server's JSON
//! responses instead of erroring.
//!
//! **Elasticity.** A placement is stamped with an **epoch** (wire v3):
//! every `HelloAck`/`StatsReply` carries it, the client establishes it
//! ring-wide exactly like the dataset shape, and a ring whose
//! endpoints disagree on it is refused. To grow or rebalance a live
//! ring, start **staging** servers ([`ShardServer::start_staging`] /
//! `shard-serve --staging`) — empty processes that answer every op
//! with a clean `staging` error — and stream each one its row range
//! with [`transfer_shard`] / [`reshard_to`]: the receiver recomputes
//! the [`wire::dataset_fingerprint`] over the bytes it landed and
//! refuses the commit on any divergence, then atomically becomes a
//! normal serving server at the new epoch. The coordinator
//! (`coordinator/server.rs` reshard op) then connects a fresh
//! [`RingClient`] with [`RemoteOptions::expect_epoch`] pinned to the
//! new epoch and swaps it in; in-flight waves drain on the old
//! client's connections (the old `Arc` lives until its last worker
//! drops it), so the flip costs zero query errors
//! (`tests/reshard.rs`).

#![deny(missing_docs)]

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::arms::{Coverage, PullEngine, PullRequest,
                               WaveTicket};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::kernels::{self, KernelChoice};
use crate::runtime::native::NativeEngine;
use crate::runtime::partition::{shard_range, WavePartition};
use crate::runtime::placement::{EndpointState, PlacementMap, RetryPolicy};
use crate::runtime::wire::{self, Message, WireRequest};

/// Default per-connection I/O timeout: long enough for a big wave to
/// compute server-side, short enough that a wedged peer can never
/// strand a coordinator worker forever. Applied to connects, writes and
/// per-wave reply waits (the demux reader itself blocks indefinitely —
/// an expired waiter kills the connection, which unblocks it).
/// Configurable via `[engine] io_timeout_ms` / `--io-timeout-ms`; this
/// constant is only the fallback when neither is given.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(60);

/// Upper bound on concurrently computing waves per server connection.
/// Further frames stay in the socket until a slot frees (TCP
/// backpressure); results are unaffected, only scheduling.
pub const MAX_CONN_WAVES: usize = 16;

// ---------------------------------------------------------------------
// shard server
// ---------------------------------------------------------------------

/// What a shard server is currently serving: the dataset slice plus
/// the placement identity it stamps into every `HelloAck`/`StatsReply`.
/// Installed exactly once — at startup for a normal server, at
/// `TransferCommit` time for a staging server — so the compute path
/// reads it lock-free.
struct ServingState {
    /// this shard's rows only (global rows `[row_start, row_start + n)`)
    local: DenseDataset,
    n_total: usize,
    row_start: usize,
    /// shard identity reported by the `Stats` health op
    shard: u64,
    of: u64,
    /// fingerprint of the served content (`wire::dataset_fingerprint`)
    data_hash: u64,
    /// placement epoch this server belongs to (`shard-serve --epoch`,
    /// or the epoch of the transfer that installed it) — a client
    /// refuses a ring whose endpoints disagree on it
    epoch: u64,
}

/// A half-streamed transfer on a staging server: declared identity and
/// row buffer accumulate here until `TransferCommit` verifies the
/// fingerprint and installs them as the [`ServingState`]. A fresh
/// `TransferBegin` replaces it wholesale, so a coordinator that
/// flapped mid-stream simply restarts the transfer.
struct PendingTransfer {
    shard: u64,
    of: u64,
    n_total: usize,
    d: usize,
    row_start: usize,
    row_end: usize,
    epoch: u64,
    rows: Vec<f32>,
}

struct ShardShared {
    /// the installed dataset + placement identity. Empty on a staging
    /// server until its transfer commits; handshake and compute ops
    /// answer a clean `staging` wire `Error` until then.
    serving: OnceLock<ServingState>,
    /// the transfer currently streaming into a staging server, if any
    staging: Mutex<Option<PendingTransfer>>,
    /// kernel tier this server's compute engines dispatch (`shard-serve
    /// --kernel`; resolved — and therefore proven available — at
    /// startup). Keep it identical across a shard's replicas: failover
    /// between tiers would change float rounding.
    kernel: KernelChoice,
    /// write timeout applied to every accepted connection, so a peer
    /// that stops reading its replies (full TCP buffers, wedged
    /// process) cannot strand a drainer thread forever. Reads stay
    /// unbounded — an idle-but-healthy coordinator is not an error.
    io_timeout: Option<Duration>,
    shutdown: AtomicBool,
    /// live connections (by id), shut down on stop so blocked I/O
    /// unblocks; each entry is removed when its handler thread exits, so
    /// a long-running server does not leak one fd per past connection
    conns: Mutex<Vec<(u64, TcpStream)>>,
    /// high-water mark of concurrently computing waves on any one
    /// connection — the server-side multiplexing witness, reported by
    /// the `Stats` op
    max_conn_waves: AtomicU64,
}

/// A running shard server (see module docs). Stops on drop; a wire
/// `Shutdown` message also stops it (that is how a `shard-serve` CLI
/// process is told to exit remotely).
pub struct ShardServer {
    /// bound address (resolved, so `host:0` shows the ephemeral port)
    pub addr: SocketAddr,
    shared: Arc<ShardShared>,
    accept_handle: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Serve `local` (the rows `[row_start, row_start + local.n)` of a
    /// global `n_total`-row dataset) on `addr` (`"host:0"` picks an
    /// ephemeral port; see `self.addr`). `shard`/`of` are the identity
    /// the `Stats` health op reports — they do not affect computation
    /// (the row range is what waves validate against).
    pub fn start(addr: &str, local: DenseDataset, n_total: usize,
                 row_start: usize, shard: usize, of: usize)
                 -> io::Result<ShardServer> {
        Self::start_with_kernel(addr, local, n_total, row_start, shard,
                                of, KernelChoice::Auto)
    }

    /// [`ShardServer::start`] with a forced row-kernel tier
    /// (`shard-serve --kernel`). The tier is resolved against this
    /// host's CPU features before the listener binds, so forcing an
    /// unavailable tier fails startup — never a wave mid-query.
    pub fn start_with_kernel(addr: &str, local: DenseDataset,
                             n_total: usize, row_start: usize,
                             shard: usize, of: usize,
                             kernel: KernelChoice)
                             -> io::Result<ShardServer> {
        Self::start_with_opts(addr, local, n_total, row_start, shard, of,
                              kernel, Some(DEFAULT_IO_TIMEOUT), 0)
    }

    /// [`ShardServer::start_with_kernel`] with an explicit per-
    /// connection write timeout (`shard-serve --io-timeout-ms`; `None`
    /// = block forever — applied to reply writes only, see
    /// `ShardShared::io_timeout`) and placement epoch (`shard-serve
    /// --epoch`; stamped into every `HelloAck`/`StatsReply`).
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_opts(addr: &str, local: DenseDataset,
                           n_total: usize, row_start: usize,
                           shard: usize, of: usize,
                           kernel: KernelChoice,
                           io_timeout: Option<Duration>,
                           epoch: u64)
                           -> io::Result<ShardServer> {
        assert!(row_start + local.n <= n_total,
                "shard rows [{row_start}, {}) exceed n_total={n_total}",
                row_start + local.n);
        let data_hash = wire::dataset_fingerprint(n_total, row_start,
                                                  &local);
        Self::start_inner(addr, kernel, io_timeout, Some(ServingState {
            local,
            n_total,
            row_start,
            shard: shard as u64,
            of: of as u64,
            data_hash,
            epoch,
        }))
    }

    /// Start an **empty** staging server (`shard-serve --staging`): it
    /// holds no dataset and answers every handshake/compute op with a
    /// clean `staging` wire `Error` until a coordinator streams it a
    /// row range (`TransferBegin`/`TransferRows`/`TransferCommit`,
    /// driven by [`transfer_shard`]) whose fingerprint verifies at
    /// commit — at which point it atomically becomes a normal serving
    /// server at the transferred placement epoch. This is how `bmonn
    /// reshard` grows a ring without restarting any process.
    pub fn start_staging(addr: &str, kernel: KernelChoice,
                         io_timeout: Option<Duration>)
                         -> io::Result<ShardServer> {
        Self::start_inner(addr, kernel, io_timeout, None)
    }

    fn start_inner(addr: &str, kernel: KernelChoice,
                   io_timeout: Option<Duration>,
                   serving: Option<ServingState>)
                   -> io::Result<ShardServer> {
        kernels::resolve(kernel).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidInput, e)
        })?;
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let cell = OnceLock::new();
        if let Some(sv) = serving {
            let _ = cell.set(sv);
        }
        let shared = Arc::new(ShardShared {
            serving: cell,
            staging: Mutex::new(None),
            kernel,
            io_timeout,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            max_conn_waves: AtomicU64::new(0),
        });
        let accept_shared = shared.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bmonn-shard-serve".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .expect("spawn shard-serve accept thread");
        Ok(ShardServer { addr, shared, accept_handle: Some(accept_handle) })
    }

    /// Slice shard `shard` of `n_shards` out of `data` (the same
    /// floor-boundary partition the ring client splits waves with) and
    /// serve it. Starting the same shard index on several machines
    /// creates replicas — any of them can serve the shard's sub-waves.
    pub fn start_shard_of(addr: &str, data: &DenseDataset, shard: usize,
                          n_shards: usize) -> io::Result<ShardServer> {
        Self::start_shard_of_with_kernel(addr, data, shard, n_shards,
                                         KernelChoice::Auto)
    }

    /// [`ShardServer::start_shard_of`] with a forced row-kernel tier —
    /// see [`ShardServer::start_with_kernel`].
    pub fn start_shard_of_with_kernel(addr: &str, data: &DenseDataset,
                                      shard: usize, n_shards: usize,
                                      kernel: KernelChoice)
                                      -> io::Result<ShardServer> {
        Self::start_shard_of_with_opts(addr, data, shard, n_shards,
                                       kernel, Some(DEFAULT_IO_TIMEOUT),
                                       0)
    }

    /// [`ShardServer::start_shard_of_with_kernel`] with an explicit
    /// per-connection write timeout and placement epoch — see
    /// [`ShardServer::start_with_opts`].
    pub fn start_shard_of_with_opts(addr: &str, data: &DenseDataset,
                                    shard: usize, n_shards: usize,
                                    kernel: KernelChoice,
                                    io_timeout: Option<Duration>,
                                    epoch: u64)
                                    -> io::Result<ShardServer> {
        let (a, b) = shard_range(shard, data.n, n_shards);
        let mut rows = Vec::with_capacity((b - a) * data.d);
        for r in a..b {
            rows.extend_from_slice(data.row(r));
        }
        Self::start_with_opts(addr,
                              DenseDataset::new(b - a, data.d, rows),
                              data.n, a, shard, n_shards, kernel,
                              io_timeout, epoch)
    }

    /// `host:port` string of the bound address.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// True once a wire `Shutdown` was received (or `stop` was called) —
    /// the `shard-serve` CLI polls this to know when to exit.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop serving: kills live connections (blocked peer reads see EOF,
    /// like a process death would produce) and joins the accept thread.
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for (_, s) in self.shared.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start one in-process shard server per shard of `data` on loopback
/// ephemeral ports — the zero-infrastructure ring used by the parity
/// tests and the `bench pull` distributed rungs.
pub fn spawn_loopback_ring(data: &DenseDataset, n_shards: usize)
                           -> Result<(Vec<ShardServer>, Vec<String>), String> {
    let mut servers = Vec::with_capacity(n_shards);
    let mut endpoints = Vec::with_capacity(n_shards);
    for i in 0..n_shards {
        let srv = ShardServer::start_shard_of("127.0.0.1:0", data, i,
                                              n_shards)
            .map_err(|e| format!("starting loopback shard {i}: {e}"))?;
        endpoints.push(srv.endpoint());
        servers.push(srv);
    }
    Ok((servers, endpoints))
}

fn accept_loop(listener: TcpListener, shared: Arc<ShardShared>) {
    let mut handles = Vec::new();
    let mut next_id = 0u64;
    // idle-poll backoff: reuse the blacklist schedule so a quiet
    // listener escalates 5 → 10 → 20 → 40 → 50 ms between polls
    // instead of spinning at a fixed 5 ms forever; any accepted
    // connection resets it, keeping accept latency low under load
    let idle = RetryPolicy {
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(50),
    };
    let mut idle_polls = 0u32;
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                idle_polls = 0;
                let id = next_id;
                next_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().push((id, clone));
                }
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = serve_conn(stream, sh.clone());
                    // deregister so past connections don't pin fds
                    sh.conns.lock().unwrap().retain(|(c, _)| *c != id);
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                idle_polls = idle_polls.saturating_add(1);
                std::thread::sleep(idle.backoff(idle_polls));
            }
            Err(_) => break,
        }
    }
    // a wire Shutdown set the flag without going through stop(): kill
    // the remaining connections so their blocked reads return, then reap
    for (_, s) in shared.conns.lock().unwrap().iter() {
        let _ = s.shutdown(Shutdown::Both);
    }
    for h in handles {
        let _ = h.join();
    }
}

fn write_locked(writer: &Mutex<TcpStream>, payload: &[u8])
                -> io::Result<()> {
    wire::write_frame(&mut *writer.lock().unwrap(), payload)
}

/// Per-wave compute state, pooled per connection so a stream of small
/// waves reuses engines and buffers instead of allocating per frame.
struct WaveScratch {
    engine: NativeEngine,
    sums: Vec<f64>,
    sqs: Vec<f64>,
    out: Vec<u8>,
}

impl WaveScratch {
    /// Fresh scratch whose engine dispatches the server's kernel tier.
    /// The tier was resolved at server startup, so construction cannot
    /// fail here.
    fn fresh(kernel: KernelChoice) -> WaveScratch {
        WaveScratch {
            engine: NativeEngine::with_options(kernel, false)
                .expect("kernel tier validated at server startup"),
            sums: Vec::new(),
            sqs: Vec::new(),
            out: Vec::new(),
        }
    }
}

/// Decoded compute waves of one connection awaiting a drainer thread,
/// plus the count of drainers currently running. Guarded by one mutex
/// so the spawn-or-enqueue decision is atomic.
struct ConnWork {
    queue: std::collections::VecDeque<Message>,
    active: usize,
}

/// One connection: framed tagged request/reply until disconnect or
/// `Shutdown`. Control ops (`Hello`/`Stats`/`Shutdown`) are answered
/// inline on the read loop; compute waves go onto a bounded queue
/// drained by up to [`MAX_CONN_WAVES`] threads, so several tagged
/// waves of this connection compute concurrently and replies leave as
/// they finish — possibly out of submission order. The read loop only
/// blocks when the queue itself is full (memory backpressure), never
/// on compute concurrency, so control ops queued behind a burst of
/// compute frames stay responsive — a loaded connection must keep
/// answering health probes (a timed-out probe would make the client
/// treat a merely-busy server as dead). A panic in a wave's compute
/// answers that wave with a wire `Error` and touches nothing else.
fn serve_conn(mut stream: TcpStream, shared: Arc<ShardShared>)
              -> io::Result<()> {
    /// decoded compute frames the read loop may buffer beyond the ones
    /// actively computing, before it applies TCP backpressure
    const MAX_QUEUED_WAVES: usize = 2 * MAX_CONN_WAVES;
    stream.set_nodelay(true)?;
    // bound reply writes so a peer that stops draining its socket
    // cannot wedge drainer threads; reads stay unbounded (idle
    // connections are healthy)
    stream.set_write_timeout(shared.io_timeout)?;
    let writer = Mutex::new(stream.try_clone()?);
    let mut inbuf = Vec::new();
    let work = Mutex::new(ConnWork {
        queue: std::collections::VecDeque::new(),
        active: 0,
    });
    let space_cv = Condvar::new();
    let scratch_pool: Mutex<Vec<WaveScratch>> = Mutex::new(Vec::new());
    std::thread::scope(|sc| -> io::Result<()> {
        loop {
            if wire::read_frame(&mut stream, &mut inbuf).is_err() {
                return Ok(()); // disconnect, kill, or corrupt framing
            }
            if wire::is_legacy_frame(&inbuf) {
                // an old (v1) client: answer in the one format it can
                // parse, then close — a clean version error, not a hang
                let mut out = Vec::new();
                wire::encode_legacy_error(&mut out, &format!(
                    "protocol version mismatch: this server speaks wire \
                     protocol v{} (wave-tagged frames); upgrade the \
                     client", wire::PROTOCOL_VERSION));
                let _ = write_locked(&writer, &out);
                return Ok(());
            }
            let msg = match Message::decode(&inbuf) {
                Err(e) => {
                    let mut out = Vec::new();
                    wire::encode_error(&mut out, wire::peek_wave_id(&inbuf),
                                       &format!("bad frame: {e}"));
                    write_locked(&writer, &out)?;
                    continue;
                }
                Ok(m) => m,
            };
            match msg {
                Message::Hello { wave_id, version } => {
                    let mut out = Vec::new();
                    if version != wire::PROTOCOL_VERSION {
                        wire::encode_error(&mut out, wave_id, &format!(
                            "protocol version mismatch: client speaks \
                             v{version}, this server speaks v{}",
                            wire::PROTOCOL_VERSION));
                    } else if let Some(sv) = shared.serving.get() {
                        wire::encode_hello_ack(
                            &mut out,
                            wave_id,
                            wire::PROTOCOL_VERSION,
                            sv.n_total as u64,
                            sv.local.d as u64,
                            sv.row_start as u64,
                            (sv.row_start + sv.local.n) as u64,
                            sv.data_hash,
                            sv.epoch,
                        );
                    } else {
                        wire::encode_error(&mut out, wave_id,
                            "staging: no dataset installed — this \
                             server is awaiting a transfer");
                    }
                    write_locked(&writer, &out)?;
                }
                Message::Stats { wave_id } => {
                    // the health op: identity + load, computed without
                    // touching the compute path (safe to poll while
                    // waves are in flight)
                    let mut out = Vec::new();
                    if let Some(sv) = shared.serving.get() {
                        let live_conns =
                            shared.conns.lock().unwrap().len() as u64;
                        wire::encode_stats_reply(
                            &mut out,
                            wave_id,
                            sv.shard,
                            sv.of,
                            sv.n_total as u64,
                            sv.local.d as u64,
                            sv.row_start as u64,
                            (sv.row_start + sv.local.n) as u64,
                            live_conns,
                            sv.data_hash,
                            shared.max_conn_waves.load(Ordering::SeqCst),
                            sv.epoch,
                        );
                    } else {
                        wire::encode_error(&mut out, wave_id,
                            "staging: no dataset installed — this \
                             server is awaiting a transfer");
                    }
                    write_locked(&writer, &out)?;
                }
                Message::TransferBegin { wave_id, shard, of, n_total, d,
                                         row_start, row_end, epoch } => {
                    // transfer ops run inline on the read loop: a
                    // staging server has no compute traffic to starve,
                    // and strict frame-order processing is exactly
                    // what a streamed row range wants
                    let mut out = Vec::new();
                    match begin_transfer(&shared, shard, of, n_total, d,
                                         row_start, row_end, epoch) {
                        Ok(()) => wire::encode_ack(&mut out, wave_id),
                        Err(e) => {
                            wire::encode_error(&mut out, wave_id, &e)
                        }
                    }
                    write_locked(&writer, &out)?;
                }
                Message::TransferRows { wave_id, row_offset, data } => {
                    let mut out = Vec::new();
                    match accept_transfer_rows(&shared, row_offset,
                                               &data) {
                        Ok(()) => wire::encode_ack(&mut out, wave_id),
                        Err(e) => {
                            wire::encode_error(&mut out, wave_id, &e)
                        }
                    }
                    write_locked(&writer, &out)?;
                }
                Message::TransferCommit { wave_id, data_hash } => {
                    let mut out = Vec::new();
                    match commit_transfer(&shared, data_hash) {
                        Ok(()) => wire::encode_ack(&mut out, wave_id),
                        Err(e) => {
                            wire::encode_error(&mut out, wave_id, &e)
                        }
                    }
                    write_locked(&writer, &out)?;
                }
                Message::Shutdown { wave_id } => {
                    shared.shutdown.store(true, Ordering::SeqCst);
                    let mut out = Vec::new();
                    wire::encode_ack(&mut out, wave_id);
                    let _ = write_locked(&writer, &out);
                    return Ok(());
                }
                m @ (Message::PartialSums { .. }
                | Message::ExactDists { .. }
                | Message::PullBatch { .. }) => {
                    let spawn_drainer = {
                        let mut w = work.lock().unwrap();
                        while w.queue.len() >= MAX_QUEUED_WAVES {
                            w = space_cv.wait(w).unwrap();
                        }
                        w.queue.push_back(m);
                        if w.active < MAX_CONN_WAVES {
                            w.active += 1;
                            shared.max_conn_waves.fetch_max(
                                w.active as u64, Ordering::SeqCst);
                            true
                        } else {
                            false
                        }
                    };
                    if spawn_drainer {
                        let shared = &shared;
                        let writer = &writer;
                        let work = &work;
                        let space_cv = &space_cv;
                        let scratch_pool = &scratch_pool;
                        sc.spawn(move || {
                            let mut scratch = scratch_pool
                                .lock()
                                .unwrap()
                                .pop()
                                .unwrap_or_else(|| {
                                    WaveScratch::fresh(shared.kernel)
                                });
                            loop {
                                let msg = {
                                    let mut w = work.lock().unwrap();
                                    match w.queue.pop_front() {
                                        Some(msg) => msg,
                                        None => {
                                            w.active -= 1;
                                            break;
                                        }
                                    }
                                };
                                space_cv.notify_one();
                                compute_wave(shared, msg, &mut scratch);
                                let _ =
                                    write_locked(writer, &scratch.out);
                            }
                            scratch_pool.lock().unwrap().push(scratch);
                        });
                    }
                }
                other => {
                    let mut out = Vec::new();
                    wire::encode_error(&mut out, other.wave_id(), &format!(
                        "unexpected {} request", other.kind()));
                    write_locked(&writer, &out)?;
                }
            }
        }
    })
}

/// Resolve one compute wave into an encoded reply frame
/// (`scratch.out`). Runs on its own thread with a pooled
/// engine/buffer set; a panic answers a wire `Error` for this wave
/// only and replaces the (possibly poisoned) scratch with a fresh one.
fn compute_wave(sh: &ShardShared, msg: Message, scratch: &mut WaveScratch) {
    let wave_id = msg.wave_id();
    let Some(sv) = sh.serving.get() else {
        wire::encode_error(&mut scratch.out, wave_id,
                           "staging: no dataset installed — this server \
                            is awaiting a transfer");
        return;
    };
    let outcome = std::panic::catch_unwind(
        std::panic::AssertUnwindSafe(|| {
            let WaveScratch { engine, sums, sqs, out } = scratch;
            match msg {
                Message::PartialSums { metric, query, rows, coord_ids,
                                       .. } => {
                    match validate_and_rebase(sv, &query, &rows,
                                              Some(&coord_ids)) {
                        Err(e) => wire::encode_error(out, wave_id, &e),
                        Ok(local_rows) => {
                            engine.partial_sums(&sv.local, &query,
                                                &local_rows, &coord_ids,
                                                metric, sums, sqs);
                            wire::encode_sums(out, wave_id, sums, sqs);
                        }
                    }
                }
                Message::ExactDists { metric, query, rows, .. } => {
                    match validate_and_rebase(sv, &query, &rows, None) {
                        Err(e) => wire::encode_error(out, wave_id, &e),
                        Ok(local_rows) => {
                            engine.exact_dists(&sv.local, &query,
                                               &local_rows, metric, sums);
                            wire::encode_dists(out, wave_id, sums);
                        }
                    }
                }
                Message::PullBatch { metric, reqs, .. } => {
                    match batch_compute(sv, engine, metric, &reqs, sums,
                                        sqs) {
                        Err(e) => wire::encode_error(out, wave_id, &e),
                        Ok(()) => {
                            wire::encode_sums(out, wave_id, sums, sqs)
                        }
                    }
                }
                other => wire::encode_error(out, wave_id, &format!(
                    "unexpected {} request", other.kind())),
            }
        }));
    if outcome.is_err() {
        *scratch = WaveScratch::fresh(sh.kernel);
        wire::encode_error(&mut scratch.out, wave_id,
                           "internal error: shard compute panicked");
    }
}

/// Check dims/coords and map global row ids onto this shard's local
/// `[0, local.n)` range.
fn validate_and_rebase(sh: &ServingState, query: &[f32], rows: &[u32],
                       coord_ids: Option<&[u32]>)
                       -> Result<Vec<u32>, String> {
    if query.len() != sh.local.d {
        return Err(format!("query dim {} != dataset dim {}", query.len(),
                           sh.local.d));
    }
    if let Some(cs) = coord_ids {
        if let Some(&j) = cs.iter().find(|&&j| j as usize >= sh.local.d) {
            return Err(format!("coordinate {j} out of range (d={})",
                               sh.local.d));
        }
    }
    let (a, b) = (sh.row_start, sh.row_start + sh.local.n);
    let mut local = Vec::with_capacity(rows.len());
    for &r in rows {
        let r = r as usize;
        if r < a || r >= b {
            return Err(format!(
                "row {r} outside this shard's range [{a}, {b})"));
        }
        local.push((r - a) as u32);
    }
    Ok(local)
}

/// Rebase and resolve a `PullBatch` wave with one engine pass; outputs
/// land in `sums`/`sqs` concatenated request-major, exactly as
/// [`PullEngine::pull_batch`] specifies.
fn batch_compute(sh: &ServingState, engine: &mut NativeEngine,
                 metric: Metric, reqs: &[WireRequest], sums: &mut Vec<f64>,
                 sqs: &mut Vec<f64>) -> Result<(), String> {
    let mut flat: Vec<u32> = Vec::new();
    let mut bounds = Vec::with_capacity(reqs.len());
    for r in reqs {
        let start = flat.len();
        let local = validate_and_rebase(sh, &r.query, &r.rows,
                                        Some(&r.coord_ids))?;
        flat.extend_from_slice(&local);
        bounds.push((start, flat.len()));
    }
    let views: Vec<PullRequest> = reqs
        .iter()
        .zip(&bounds)
        .map(|(r, &(a, b))| PullRequest {
            query: &r.query,
            rows: &flat[a..b],
            coord_ids: &r.coord_ids,
        })
        .collect();
    engine.pull_batch(&sh.local, &views, metric, sums, sqs);
    Ok(())
}

// ---------------------------------------------------------------------
// staging-side transfer handlers
// ---------------------------------------------------------------------

/// Validate a `TransferBegin` against the canonical partition and open
/// (or restart) the staging buffer. Only a staging server accepts it —
/// a serving server's placement is immutable (a ring grows by starting
/// fresh staging processes, never by overwriting live ones).
#[allow(clippy::too_many_arguments)]
fn begin_transfer(sh: &ShardShared, shard: u64, of: u64, n_total: u64,
                  d: u64, row_start: u64, row_end: u64, epoch: u64)
                  -> Result<(), String> {
    if sh.serving.get().is_some() {
        return Err("transfers are accepted only by a staging server \
                    (shard-serve --staging); this server already serves \
                    a dataset"
            .into());
    }
    if n_total == 0 || d == 0 {
        return Err(format!(
            "transfer declares an empty dataset (n={n_total}, d={d})"));
    }
    if of == 0 || shard >= of {
        return Err(format!("transfer declares shard {shard} of {of}"));
    }
    let (n_us, d_us) = (n_total as usize, d as usize);
    let (a, b) = (row_start as usize, row_end as usize);
    if b < a || b > n_us {
        return Err(format!(
            "transfer rows [{a}, {b}) are not a slice of n={n_total}"));
    }
    let (wa, wb) = shard_range(shard as usize, n_us, of as usize);
    if (a, b) != (wa, wb) {
        return Err(format!(
            "transfer rows [{a}, {b}) but the {of}-way partition of \
             n={n_total} assigns [{wa}, {wb}) to shard {shard}"));
    }
    let floats = (b - a).checked_mul(d_us).ok_or_else(|| {
        format!("transfer of {} rows x {d} dims overflows", b - a)
    })?;
    // a fresh begin replaces any half-streamed transfer, so a
    // coordinator that flapped mid-stream restarts cleanly instead of
    // corrupting the buffer
    *sh.staging.lock().unwrap() = Some(PendingTransfer {
        shard,
        of,
        n_total: n_us,
        d: d_us,
        row_start: a,
        row_end: b,
        epoch,
        rows: vec![0.0; floats],
    });
    Ok(())
}

/// Land one `TransferRows` chunk into the staging buffer at its
/// declared row offset (relative to the transfer's `row_start`).
fn accept_transfer_rows(sh: &ShardShared, row_offset: u64, data: &[f32])
                        -> Result<(), String> {
    let mut staging = sh.staging.lock().unwrap();
    let Some(p) = staging.as_mut() else {
        return Err(
            "no transfer in progress — send transfer_begin first".into());
    };
    if data.len() % p.d != 0 {
        return Err(format!(
            "transfer chunk of {} floats is not whole rows of d={}",
            data.len(), p.d));
    }
    let rows_in = data.len() / p.d;
    let off = row_offset as usize;
    let range = p.row_end - p.row_start;
    if off > range || rows_in > range - off {
        return Err(format!(
            "transfer chunk rows [{off}, {}) overflow the declared \
             range of {range} rows",
            off.saturating_add(rows_in)));
    }
    p.rows[off * p.d..(off + rows_in) * p.d].copy_from_slice(data);
    Ok(())
}

/// Verify the streamed bytes against the coordinator's fingerprint and
/// install them as the serving state. The pending transfer is consumed
/// either way — a failed commit requires a full restart, the only
/// honest recovery from a diverged stream.
fn commit_transfer(sh: &ShardShared, data_hash: u64)
                   -> Result<(), String> {
    let Some(p) = sh.staging.lock().unwrap().take() else {
        return Err(
            "no transfer in progress — send transfer_begin first".into());
    };
    let local = DenseDataset::new(p.row_end - p.row_start, p.d, p.rows);
    let fp = wire::dataset_fingerprint(p.n_total, p.row_start, &local);
    if fp != data_hash {
        return Err(format!(
            "transfer fingerprint mismatch: received rows hash \
             {fp:#018x} but the coordinator sent {data_hash:#018x} — \
             restart the transfer"));
    }
    sh.serving
        .set(ServingState {
            local,
            n_total: p.n_total,
            row_start: p.row_start,
            shard: p.shard,
            of: p.of,
            data_hash: fp,
            epoch: p.epoch,
        })
        .map_err(|_| {
            "another transfer already installed a dataset on this \
             server"
                .to_string()
        })
}

// ---------------------------------------------------------------------
// health probe (client side of the Stats op)
// ---------------------------------------------------------------------

/// Health snapshot of one shard-server endpoint (the wire `Stats` op):
/// what shard it serves, of which ring size, over which dataset, its
/// dataset fingerprint and how many connections it currently holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EndpointStats {
    /// shard index this server was started as (`shard-serve --shard`)
    pub shard: usize,
    /// ring size it was started for (`shard-serve --of`) — this is what
    /// lets a coordinator size `--remote` from a single live endpoint
    pub of: usize,
    /// global dataset row count
    pub n_total: usize,
    /// dataset dimension
    pub d: usize,
    /// first owned global row
    pub row_start: usize,
    /// one past the last owned global row
    pub row_end: usize,
    /// connections the server currently holds (including this probe's)
    pub live_conns: usize,
    /// fingerprint of the served rows — replicas of one shard must
    /// agree on it (`bmonn ring-stats` exits nonzero on divergence)
    pub data_hash: u64,
    /// high-water mark of concurrently computing waves the server has
    /// seen on any single connection (the multiplexing witness)
    pub max_conn_waves: usize,
    /// placement epoch the server carries — every endpoint of a
    /// placement must agree on it, and `bmonn reshard` verifies the
    /// new ring reports the new epoch before any traffic flips
    pub epoch: u64,
}

/// Probe one endpoint with the wire `Stats` health op over a fresh
/// connection. Used by `bmonn ring-stats` to survey a ring's health and
/// layout without issuing any compute. An old-protocol (v1) endpoint
/// reports a clean version-mismatch error.
pub fn endpoint_stats(endpoint: &str, timeout: Option<Duration>)
                      -> Result<EndpointStats, String> {
    let mut stream = connect_endpoint(endpoint, timeout)
        .map_err(|e| format!("{endpoint}: connect failed: {e}"))?;
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    stream.set_read_timeout(timeout).map_err(|e| e.to_string())?;
    stream.set_write_timeout(timeout).map_err(|e| e.to_string())?;
    let mut buf = Vec::new();
    wire::encode_stats(&mut buf, 1);
    wire::write_frame(&mut stream, &buf)
        .map_err(|e| format!("{endpoint}: send failed: {e}"))?;
    wire::read_frame(&mut stream, &mut buf)
        .map_err(|e| format!("{endpoint}: recv failed: {e}"))?;
    match Message::decode(&buf)
        .map_err(|e| format!("{endpoint}: bad reply: {e}"))?
    {
        Message::StatsReply {
            shard, of, n_total, d, row_start, row_end, live_conns,
            data_hash, max_conn_waves, epoch, ..
        } => Ok(EndpointStats {
            shard: shard as usize,
            of: of as usize,
            n_total: n_total as usize,
            d: d as usize,
            row_start: row_start as usize,
            row_end: row_end as usize,
            live_conns: live_conns as usize,
            data_hash,
            max_conn_waves: max_conn_waves as usize,
            epoch,
        }),
        Message::Error { msg, .. } => Err(format!("{endpoint}: {msg}")),
        other => Err(format!("{endpoint}: unexpected {} reply",
                             other.kind())),
    }
}

// ---------------------------------------------------------------------
// transfer drivers (client side of the reshard op)
// ---------------------------------------------------------------------

/// Rows per `TransferRows` frame when streaming a shard to a staging
/// server — small enough to keep every frame far under the decoder's
/// frame cap for any sane dimension, large enough that per-frame
/// round-trip overhead is noise.
const TRANSFER_CHUNK_ROWS: usize = 512;

/// One blocking transfer round-trip: write the staged frame, read the
/// reply, demand the matching `Ack`.
fn transfer_step(stream: &mut TcpStream, buf: &mut Vec<u8>,
                 endpoint: &str, wid: u64, what: &str)
                 -> Result<(), String> {
    wire::write_frame(stream, buf)
        .map_err(|e| format!("{endpoint}: {what} send failed: {e}"))?;
    wire::read_frame(stream, buf)
        .map_err(|e| format!("{endpoint}: {what} recv failed: {e}"))?;
    match Message::decode(buf)
        .map_err(|e| format!("{endpoint}: bad {what} reply: {e}"))?
    {
        Message::Ack { wave_id } if wave_id == wid => Ok(()),
        Message::Error { msg, .. } => {
            Err(format!("{endpoint}: {what} rejected: {msg}"))
        }
        other => Err(format!("{endpoint}: unexpected {} reply to {what}",
                             other.kind())),
    }
}

/// Stream shard `shard` of `n_shards` of `data` to the staging server
/// at `endpoint` and commit it at placement `epoch`. The transfer is
/// verified end to end with [`wire::dataset_fingerprint`]: the
/// receiver recomputes the fingerprint over the bytes it actually
/// landed and refuses the commit on any divergence (a missing or
/// corrupted chunk can never install). Returns the fingerprint the
/// installed server now serves. The target must be a staging server
/// ([`ShardServer::start_staging`] / `shard-serve --staging`) — a
/// serving server refuses `TransferBegin`.
pub fn transfer_shard(endpoint: &str, data: &DenseDataset, shard: usize,
                      n_shards: usize, epoch: u64,
                      timeout: Option<Duration>) -> Result<u64, String> {
    let (a, b) = shard_range(shard, data.n, n_shards);
    let mut stream = connect_endpoint(endpoint, timeout)
        .map_err(|e| format!("{endpoint}: connect failed: {e}"))?;
    stream.set_nodelay(true).map_err(|e| format!("{endpoint}: {e}"))?;
    stream
        .set_read_timeout(timeout)
        .map_err(|e| format!("{endpoint}: {e}"))?;
    stream
        .set_write_timeout(timeout)
        .map_err(|e| format!("{endpoint}: {e}"))?;
    let mut wid = 1u64;
    let mut buf = Vec::new();
    wire::encode_transfer_begin(&mut buf, wid, shard as u64,
                                n_shards as u64, data.n as u64,
                                data.d as u64, a as u64, b as u64,
                                epoch);
    transfer_step(&mut stream, &mut buf, endpoint, wid,
                  "transfer_begin")?;
    let mut r = a;
    while r < b {
        let r1 = (r + TRANSFER_CHUNK_ROWS).min(b);
        wid += 1;
        wire::encode_transfer_rows(&mut buf, wid, (r - a) as u64,
                                   &data.raw()[r * data.d..r1 * data.d]);
        transfer_step(&mut stream, &mut buf, endpoint, wid,
                      "transfer_rows")?;
        r = r1;
    }
    let local = DenseDataset::new(
        b - a, data.d, data.raw()[a * data.d..b * data.d].to_vec());
    let fp = wire::dataset_fingerprint(data.n, a, &local);
    wid += 1;
    wire::encode_transfer_commit(&mut buf, wid, fp);
    transfer_step(&mut stream, &mut buf, endpoint, wid,
                  "transfer_commit")?;
    Ok(fp)
}

/// Populate a whole new placement: stream every shard of `data` to
/// each of its replicas in `to` (all staging servers) at placement
/// `epoch`, then verify the installed ring endpoint by endpoint with
/// the `Stats` op — identity, row range, fingerprint and epoch must
/// all check out before the caller flips any traffic onto it. Returns
/// the per-shard fingerprints. Nothing here mutates existing servers,
/// so on any failure the old placement simply keeps serving.
pub fn reshard_to(data: &DenseDataset, to: &PlacementMap, epoch: u64,
                  timeout: Option<Duration>)
                  -> Result<Vec<u64>, String> {
    let s = to.n_shards();
    let mut fps = Vec::with_capacity(s);
    for shard in 0..s {
        let mut fp = None;
        for ep in to.replicas(shard) {
            let f = transfer_shard(ep, data, shard, s, epoch, timeout)?;
            if let Some(f0) = fp {
                debug_assert_eq!(f0, f, "one slice, one fingerprint");
            }
            fp = Some(f);
        }
        fps.push(fp.expect("PlacementMap rejects empty replica lists"));
    }
    for shard in 0..s {
        let (wa, wb) = shard_range(shard, data.n, s);
        for ep in to.replicas(shard) {
            let st = endpoint_stats(ep, timeout)?;
            if st.shard != shard
                || st.of != s
                || st.n_total != data.n
                || (st.row_start, st.row_end) != (wa, wb)
            {
                return Err(format!(
                    "{ep}: serves shard {}/{} rows [{}, {}) after the \
                     transfer, expected shard {shard}/{s} rows \
                     [{wa}, {wb})",
                    st.shard, st.of, st.row_start, st.row_end));
            }
            if st.data_hash != fps[shard] {
                return Err(format!(
                    "{ep}: fingerprint {:#018x} after the transfer, \
                     expected {:#018x}",
                    st.data_hash, fps[shard]));
            }
            if st.epoch != epoch {
                return Err(format!(
                    "{ep}: placement epoch {} after the transfer, \
                     expected {epoch}",
                    st.epoch));
            }
        }
    }
    Ok(fps)
}

// ---------------------------------------------------------------------
// ring client (multiplexed)
// ---------------------------------------------------------------------

/// Completion slot of one in-flight tagged sub-wave.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

enum SlotState {
    Waiting,
    Reply(Message),
    /// the connection died (or was killed) before the reply arrived
    Dead(String),
}

enum SlotWait {
    Reply(Message),
    Dead(String),
    TimedOut,
}

impl Slot {
    fn new() -> Slot {
        Slot { state: Mutex::new(SlotState::Waiting), cv: Condvar::new() }
    }

    fn fulfill(&self, m: Message) {
        *self.state.lock().unwrap() = SlotState::Reply(m);
        self.cv.notify_all();
    }

    fn fail(&self, e: &str) {
        let mut st = self.state.lock().unwrap();
        if matches!(*st, SlotState::Waiting) {
            *st = SlotState::Dead(e.to_string());
        }
        drop(st);
        self.cv.notify_all();
    }

    fn wait(&self, timeout: Option<Duration>) -> SlotWait {
        self.wait_until(timeout.map(|t| Instant::now() + t))
    }

    /// [`Slot::wait`] against an absolute deadline — the primitive the
    /// budget-aware sub-wave wait builds on (the effective deadline is
    /// the earlier of the I/O window and the query budget).
    fn wait_until(&self, deadline: Option<Instant>) -> SlotWait {
        let mut st = self.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, SlotState::Waiting) {
                SlotState::Reply(m) => return SlotWait::Reply(m),
                SlotState::Dead(e) => return SlotWait::Dead(e),
                SlotState::Waiting => {}
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        return SlotWait::TimedOut;
                    }
                    let (g, _) =
                        self.cv.wait_timeout(st, dl - now).unwrap();
                    st = g;
                }
            }
        }
    }
}

/// One live multiplexed connection: a writer shared by every submitting
/// caller, the demux reader's pending-slot table, and a dedicated
/// shutdown handle so a wedged writer can never block the kill path.
struct Conn {
    ep_idx: usize,
    endpoint: String,
    writer: Mutex<TcpStream>,
    shut: TcpStream,
    pending: Mutex<HashMap<u64, Arc<Slot>>>,
    dead: AtomicBool,
}

impl Conn {
    /// Mark dead, unblock the reader, fail every in-flight slot — each
    /// failed sub-wave then re-issues itself to the next replica.
    /// Returns true for the call that actually performed the kill
    /// (idempotent: later callers get false).
    fn kill(&self, err: &str) -> bool {
        if self.dead.swap(true, Ordering::SeqCst) {
            return false;
        }
        let _ = self.shut.shutdown(Shutdown::Both);
        let mut p = self.pending.lock().unwrap();
        for (_, slot) in p.drain() {
            slot.fail(err);
        }
        true
    }
}

/// Per-endpoint blacklist state plus the shard's live connection.
struct ShardInner {
    states: Vec<EndpointState>,
    conns: Vec<Option<Arc<Conn>>>,
    /// dataset fingerprint every replica of this shard must serve —
    /// adopted from the first successful handshake, then enforced on
    /// every later one (failover targets and healed replicas included)
    hash: Option<u64>,
}

/// One logical shard of the ring: ordered replica endpoints, blacklist
/// bookkeeping, and the machinery to (re)establish the single live
/// multiplexed connection.
struct ShardState {
    shard: usize,
    n_shards: usize,
    endpoints: Vec<String>,
    timeout: Option<Duration>,
    retry: RetryPolicy,
    /// ring-global (n, d), shared by every shard of the client — set by
    /// the first successful handshake anywhere in the ring
    shape: Arc<Mutex<Option<(usize, usize)>>>,
    /// ring-global placement epoch, established exactly like `shape`:
    /// adopted from the first successful handshake, then enforced on
    /// every later one — endpoints of one placement must agree
    ring_epoch: Arc<Mutex<Option<u64>>>,
    /// refuse endpoints that are not at this exact placement epoch
    /// ([`RemoteOptions::expect_epoch`])
    expect_epoch: Option<u64>,
    next_wave: Arc<AtomicU64>,
    /// ring-wide high-water mark of concurrently pending sub-waves on
    /// any one connection (the client-side multiplexing witness)
    max_inflight: Arc<AtomicU64>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    inner: Mutex<ShardInner>,
}

impl ShardState {
    /// Hand out a live connection on an endpoint not yet attempted by
    /// this sub-wave: an existing healthy conn first (replica order),
    /// else dial + handshake one, recording failures against the
    /// per-endpoint backoff. The dial + handshake itself runs
    /// **without** the shard lock — a slow or blackholed endpoint must
    /// not stall other callers' submits on healthy connections, the
    /// demux readers' kill path, or coverage probes.
    fn get_conn(self: &Arc<Self>, attempted: &mut [bool],
                errors: &mut Vec<String>) -> Option<Arc<Conn>> {
        loop {
            // under the lock: reuse a live conn, or pick a dial target
            let target = {
                let mut inner = self.inner.lock().unwrap();
                let mut pick = None;
                for i in 0..self.endpoints.len() {
                    if attempted[i] {
                        continue;
                    }
                    if let Some(c) = &inner.conns[i] {
                        if !c.dead.load(Ordering::SeqCst) {
                            attempted[i] = true;
                            return Some(c.clone());
                        }
                        inner.conns[i] = None;
                    }
                }
                for i in 0..self.endpoints.len() {
                    if attempted[i]
                        || !inner.states[i].eligible(Instant::now())
                    {
                        continue;
                    }
                    attempted[i] = true;
                    pick = Some(i);
                    break;
                }
                pick
            };
            let idx = target?;
            match self.dial_endpoint(idx) {
                Ok((stream, hash)) => match self
                    .install_conn(idx, stream, hash)
                {
                    Ok(c) => return Some(c),
                    Err(e) => errors.push(e),
                },
                Err(e) => {
                    self.inner.lock().unwrap().states[idx]
                        .record_failure(&self.retry, Instant::now());
                    errors.push(e);
                }
            }
        }
    }

    /// Dial endpoint `idx` and run the full handshake — version, ring
    /// shape and canonical row range validated — returning the
    /// configured stream and the replica's dataset fingerprint. Takes
    /// no shard lock (the ring-global shape has its own).
    fn dial_endpoint(&self, idx: usize)
                     -> Result<(TcpStream, u64), String> {
        let ep = self.endpoints[idx].clone();
        let mut stream = connect_endpoint(&ep, self.timeout)
            .map_err(|e| format!("{ep}: connect failed: {e}"))?;
        stream.set_nodelay(true).map_err(|e| format!("{ep}: {e}"))?;
        stream
            .set_write_timeout(self.timeout)
            .map_err(|e| format!("{ep}: {e}"))?;
        // the handshake is a plain blocking round-trip: bound its read
        stream
            .set_read_timeout(self.timeout)
            .map_err(|e| format!("{ep}: {e}"))?;
        let wid = self.next_wave.fetch_add(1, Ordering::SeqCst);
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf, wid, wire::PROTOCOL_VERSION);
        wire::write_frame(&mut stream, &buf)
            .map_err(|e| format!("{ep}: handshake send failed: {e}"))?;
        wire::read_frame(&mut stream, &mut buf)
            .map_err(|e| format!("{ep}: handshake recv failed: {e}"))?;
        let (version, n, d, a, b, hash, epoch) =
            match Message::decode(&buf)
                .map_err(|e| format!("{ep}: bad handshake reply: {e}"))?
        {
            Message::HelloAck {
                version, n_total, d, row_start, row_end, data_hash,
                epoch, ..
            } => (version, n_total as usize, d as usize,
                  row_start as usize, row_end as usize, data_hash,
                  epoch),
            Message::Error { msg, .. } => {
                return Err(format!("{ep}: rejected the handshake: {msg}"))
            }
            other => {
                return Err(format!("{ep}: unexpected {} handshake reply",
                                   other.kind()))
            }
        };
        if version != wire::PROTOCOL_VERSION {
            return Err(format!(
                "{ep}: speaks wire protocol v{version}; this build speaks \
                 v{} — upgrade the peer", wire::PROTOCOL_VERSION));
        }
        if let Some(want) = self.expect_epoch {
            if epoch != want {
                return Err(format!(
                    "{ep}: placement epoch {epoch} but the coordinator \
                     expects epoch {want} — is this endpoint part of the \
                     old placement?"));
            }
        }
        {
            let mut e = self.ring_epoch.lock().unwrap();
            match *e {
                Some(e0) if e0 != epoch => {
                    return Err(format!(
                        "{ep}: placement epoch {epoch} diverges from the \
                         ring's established epoch {e0} — every endpoint \
                         of a placement must carry one epoch"));
                }
                Some(_) => {}
                None => *e = Some(epoch),
            }
        }
        {
            let mut shape = self.shape.lock().unwrap();
            match *shape {
                Some((n0, d0)) if (n0, d0) != (n, d) => {
                    return Err(format!(
                        "{ep} serves n={n} d={d} but the ring serves \
                         n={n0} d={d0} — every replica must load one \
                         dataset"));
                }
                Some(_) => {}
                None => *shape = Some((n, d)),
            }
        }
        let (wa, wb) = shard_range(self.shard, n, self.n_shards);
        if (a, b) != (wa, wb) {
            return Err(format!(
                "{ep} serves rows [{a}, {b}) but the {}-way partition of \
                 n={n} assigns [{wa}, {wb}) to shard {} — start it as \
                 shard {} of {}",
                self.n_shards, self.shard, self.shard, self.n_shards));
        }
        // multiplexed phase: the demux reader blocks in read_frame
        // indefinitely; waiters enforce the timeout and kill the
        // connection when it expires, which unblocks the reader
        stream
            .set_read_timeout(None)
            .map_err(|e| format!("{ep}: {e}"))?;
        Ok((stream, hash))
    }

    /// Validate the replica's fingerprint against its shard-mates' and
    /// install the handshaken connection (spawning its demux reader).
    /// If a concurrent caller installed a live connection to the same
    /// endpoint first, the fresh socket is discarded and the
    /// established one handed back.
    fn install_conn(self: &Arc<Self>, idx: usize, stream: TcpStream,
                    hash: u64) -> Result<Arc<Conn>, String> {
        let ep = self.endpoints[idx].clone();
        let mut inner = self.inner.lock().unwrap();
        if let Some(c) = &inner.conns[idx] {
            if !c.dead.load(Ordering::SeqCst) {
                // lost a dial race: prefer the established connection
                // (our fresh stream closes on drop)
                return Ok(c.clone());
            }
            inner.conns[idx] = None;
        }
        match inner.hash {
            None => inner.hash = Some(hash),
            Some(h0) if h0 != hash => {
                inner.states[idx].record_failure(&self.retry,
                                                 Instant::now());
                return Err(format!(
                    "{ep}: dataset fingerprint {hash:#018x} diverges from \
                     shard {}'s established fingerprint {h0:#018x} — \
                     every replica of a shard must serve identical data",
                    self.shard));
            }
            Some(_) => {}
        }
        let shut = stream.try_clone().map_err(|e| format!("{ep}: {e}"))?;
        let reader_stream =
            stream.try_clone().map_err(|e| format!("{ep}: {e}"))?;
        let conn = Arc::new(Conn {
            ep_idx: idx,
            endpoint: ep.clone(),
            writer: Mutex::new(stream),
            shut,
            pending: Mutex::new(HashMap::new()),
            dead: AtomicBool::new(false),
        });
        let me = self.clone();
        let rc = conn.clone();
        let handle = std::thread::Builder::new()
            .name(format!("bmonn-ring-s{}r{idx}", self.shard))
            .spawn(move || reader_loop(me, rc, reader_stream))
            .map_err(|e| format!("{ep}: spawning demux reader: {e}"))?;
        {
            // reap finished demux readers so a long-lived client with a
            // flapping endpoint does not accumulate handles unboundedly
            let mut readers = self.readers.lock().unwrap();
            readers.retain(|h| !h.is_finished());
            readers.push(handle);
        }
        inner.states[idx].record_success();
        inner.conns[idx] = Some(conn.clone());
        Ok(conn)
    }

    /// Kill a connection and blacklist its endpoint (I/O failure path).
    /// Only the first kill of a connection charges the endpoint's
    /// backoff — the reader and a timed-out waiter may race here.
    fn kill_conn(&self, conn: &Arc<Conn>, err: &str) {
        let first = conn.kill(err);
        let mut inner = self.inner.lock().unwrap();
        if first {
            inner.states[conn.ep_idx].record_failure(&self.retry,
                                                     Instant::now());
        }
        if let Some(cur) = &inner.conns[conn.ep_idx] {
            if Arc::ptr_eq(cur, conn) {
                inner.conns[conn.ep_idx] = None;
            }
        }
    }
}

/// Demultiplexing reader: one per live connection. Routes every reply
/// frame to its wave's completion slot by tag; any read/decode failure
/// or an unmatched tag kills the connection, which fails the in-flight
/// sub-waves over to the next replica.
fn reader_loop(shard: Arc<ShardState>, conn: Arc<Conn>,
               mut stream: TcpStream) {
    let mut buf = Vec::new();
    loop {
        if conn.dead.load(Ordering::SeqCst) {
            return;
        }
        if let Err(e) = wire::read_frame(&mut stream, &mut buf) {
            shard.kill_conn(&conn,
                            &format!("{}: recv failed: {e}", conn.endpoint));
            return;
        }
        let msg = match Message::decode(&buf) {
            Err(e) => {
                shard.kill_conn(
                    &conn,
                    &format!("{}: bad reply: {e}", conn.endpoint));
                return;
            }
            Ok(m) => m,
        };
        let wid = msg.wave_id();
        let slot = conn.pending.lock().unwrap().remove(&wid);
        match slot {
            Some(s) => s.fulfill(msg),
            None => {
                shard.kill_conn(&conn, &format!(
                    "{}: reply for unknown wave {wid} — stream out of \
                     sync", conn.endpoint));
                return;
            }
        }
    }
}

/// One staged, in-flight sub-wave: the encoded payload (owned, so a
/// failover can re-issue identical bytes), the per-endpoint attempt
/// set bounding retries, and the completion slot of the current
/// attempt. Created by [`RingClient::submit_to_shard`] — the frame is
/// on the wire when that returns.
struct SubWave {
    shard: Arc<ShardState>,
    wave_id: u64,
    payload: Vec<u8>,
    attempted: Vec<bool>,
    errors: Vec<String>,
    current: Option<(Arc<Conn>, Arc<Slot>)>,
    /// absolute query-budget deadline: past it, `wait` stops failing
    /// over and returns a [`wire::DEADLINE_ERROR`]-classified error
    /// immediately instead of running out the per-attempt I/O timeout
    deadline: Option<Instant>,
}

impl SubWave {
    fn submit(shard: Arc<ShardState>, wave_id: u64, payload: Vec<u8>,
              deadline: Option<Instant>) -> SubWave {
        let n = shard.endpoints.len();
        let mut sw = SubWave {
            shard,
            wave_id,
            payload,
            attempted: vec![false; n],
            errors: Vec::new(),
            current: None,
            deadline,
        };
        // best effort: a submit-time failure (no live replica right
        // now) is retried — and surfaced — at wait() time
        sw.dispatch();
        sw
    }

    /// Register the completion slot and put the payload on the wire of
    /// the next eligible replica. Returns false when every replica has
    /// been attempted or is backed off.
    fn dispatch(&mut self) -> bool {
        loop {
            let Some(conn) =
                self.shard.get_conn(&mut self.attempted, &mut self.errors)
            else {
                return false;
            };
            let slot = Arc::new(Slot::new());
            {
                let mut p = conn.pending.lock().unwrap();
                if conn.dead.load(Ordering::SeqCst) {
                    // died between handout and registration
                    self.errors.push(format!(
                        "{}: connection died before send", conn.endpoint));
                    continue;
                }
                p.insert(self.wave_id, slot.clone());
                self.shard
                    .max_inflight
                    .fetch_max(p.len() as u64, Ordering::SeqCst);
            }
            let sent = {
                let mut w = conn.writer.lock().unwrap();
                wire::write_frame(&mut *w, &self.payload)
            };
            match sent {
                Ok(()) => {
                    self.current = Some((conn, slot));
                    return true;
                }
                Err(e) => {
                    let msg =
                        format!("{}: send failed: {e}", conn.endpoint);
                    self.shard.kill_conn(&conn, &msg);
                    self.errors.push(msg);
                }
            }
        }
    }

    /// The query budget ran out: kill the current attempt's connection
    /// (exactly like an I/O timeout — the reply may never come, and a
    /// killed conn cannot leak its pending slot) and surface a
    /// [`wire::is_deadline_error`]-classified error. No failover: there
    /// is no budget left to spend on another replica.
    fn deadline_error(&mut self) -> String {
        if let Some((conn, _)) = self.current.take() {
            let e = format!("{}: {}: query budget exhausted mid-wave",
                            conn.endpoint, wire::DEADLINE_ERROR);
            self.shard.kill_conn(&conn, &e);
        }
        format!("shard {}: {}: query budget exhausted",
                self.shard.shard, wire::DEADLINE_ERROR)
    }

    /// Block until this sub-wave's reply arrives, transparently failing
    /// over: a dead connection or timeout blacklists the replica and
    /// re-issues the identical payload to the next one; a wire `Error`
    /// reply fails over without blacklisting (the connection is
    /// healthy). Each endpoint is attempted at most once. A query
    /// budget (`deadline`) bounds the whole wait: each attempt waits
    /// until the earlier of its I/O window and the budget, and an
    /// expired budget returns a deadline error instead of failing over.
    fn wait(mut self) -> Result<Message, String> {
        loop {
            // budget gate: an exhausted query must neither dispatch
            // nor keep waiting on anything
            if self.deadline.is_some_and(|dl| Instant::now() >= dl) {
                return Err(self.deadline_error());
            }
            let Some((conn, slot)) = self.current.take() else {
                if !self.dispatch() {
                    let detail = if self.errors.is_empty() {
                        "all replicas are backed off after recent \
                         failures"
                            .to_string()
                    } else {
                        self.errors.join("; ")
                    };
                    return Err(format!("shard {}: no live replica: \
                                        {detail}", self.shard.shard));
                }
                continue;
            };
            // this attempt's wait bound: the earlier of the I/O window
            // and the remaining query budget
            let io_dl = self.shard.timeout.map(|t| Instant::now() + t);
            let eff = match (io_dl, self.deadline) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            match slot.wait_until(eff) {
                SlotWait::Reply(Message::Error { msg, .. }) => {
                    // server-side failure on a healthy connection: keep
                    // the conn (and the endpoint's clean record), fail
                    // only this sub-wave over to the next replica
                    self.errors
                        .push(format!("{}: {msg}", conn.endpoint));
                }
                SlotWait::Reply(m) => return Ok(m),
                SlotWait::Dead(e) => {
                    // connection killed — blacklist already recorded
                    self.errors.push(e);
                }
                SlotWait::TimedOut => {
                    if self.deadline
                        .is_some_and(|dl| Instant::now() >= dl)
                    {
                        self.current = Some((conn, slot));
                        return Err(self.deadline_error());
                    }
                    let e =
                        format!("{}: request timed out", conn.endpoint);
                    self.shard.kill_conn(&conn, &e);
                    self.errors.push(e);
                }
            }
        }
    }
}

/// The shared, multiplexed ring client (see module docs): one
/// connection set per process, safely shared by every worker thread via
/// `Arc`. Sub-waves from any number of concurrent callers interleave on
/// each shard's single connection and their replies are demultiplexed
/// by wave tag. Construct once ([`RingClient::connect`] /
/// [`RingClient::connect_opts`]) and hand clones of the `Arc` to every
/// [`RemoteEngine`].
pub struct RingClient {
    shards: Vec<Arc<ShardState>>,
    n_total: usize,
    d: usize,
    degraded: bool,
    ring_epoch: Arc<Mutex<Option<u64>>>,
    next_wave: Arc<AtomicU64>,
    max_inflight: Arc<AtomicU64>,
    readers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl RingClient {
    /// Connect to a ring given one spec per shard (replicas separated by
    /// `|` within a spec) with default options.
    pub fn connect(endpoints: &[String]) -> Result<RingClient, String> {
        Self::connect_opts(&PlacementMap::parse(endpoints)?,
                           RemoteOptions::default())
    }

    /// Connect to every shard's first live replica of `placement`,
    /// verifying version, shape, canonical row range and dataset
    /// fingerprint per replica. Without `opts.degraded`, a shard with
    /// no live replica fails the connect; with it, the shard starts out
    /// down (its rows are excluded from [`RingClient::coverage`]) and
    /// is re-probed as its endpoints' backoffs expire — at least one
    /// shard must be reachable either way, to learn the dataset shape.
    pub fn connect_opts(placement: &PlacementMap, opts: RemoteOptions)
                        -> Result<RingClient, String> {
        let s = placement.n_shards();
        let shape = Arc::new(Mutex::new(None));
        let ring_epoch = Arc::new(Mutex::new(None));
        let next_wave = Arc::new(AtomicU64::new(1));
        let max_inflight = Arc::new(AtomicU64::new(0));
        let readers: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let mut shards: Vec<Arc<ShardState>> = Vec::with_capacity(s);
        let mut fail: Option<String> = None;
        for i in 0..s {
            let eps = placement.replicas(i).to_vec();
            let n_eps = eps.len();
            let st = Arc::new(ShardState {
                shard: i,
                n_shards: s,
                endpoints: eps,
                timeout: opts.timeout,
                retry: opts.retry,
                shape: shape.clone(),
                ring_epoch: ring_epoch.clone(),
                expect_epoch: opts.expect_epoch,
                next_wave: next_wave.clone(),
                max_inflight: max_inflight.clone(),
                readers: readers.clone(),
                inner: Mutex::new(ShardInner {
                    states: vec![EndpointState::default(); n_eps],
                    conns: vec![None; n_eps],
                    hash: None,
                }),
            });
            // eager connect: learns shape + fingerprint, and surfaces
            // dead shards at startup unless degraded mode allows them
            let mut attempted = vec![false; st.endpoints.len()];
            let mut errors = Vec::new();
            if st.get_conn(&mut attempted, &mut errors).is_none()
                && !opts.degraded
            {
                fail = Some(format!("shard {i}: no live replica: {}",
                                    errors.join("; ")));
                shards.push(st);
                break;
            }
            shards.push(st);
        }
        let resolved = *shape.lock().unwrap();
        let fail = fail.or_else(|| match resolved {
            Some(_) => None,
            None => Some(
                "no shard of the ring is reachable — cannot learn the \
                 dataset shape (degraded mode still needs at least one \
                 live shard)"
                    .into(),
            ),
        });
        if let Some(e) = fail {
            // tear down whatever connected before the failure so no
            // reader thread or socket outlives the failed construction
            shutdown_shards(&shards, &readers);
            return Err(e);
        }
        let (n_total, d) = resolved.unwrap();
        Ok(RingClient {
            shards,
            n_total,
            d,
            degraded: opts.degraded,
            ring_epoch,
            next_wave,
            max_inflight,
            readers,
        })
    }

    /// The placement epoch this ring established at handshake (every
    /// endpoint must agree on it; 0 when no handshake has succeeded
    /// yet, which is also the epoch of a never-resharded ring).
    pub fn epoch(&self) -> u64 {
        self.ring_epoch.lock().unwrap().unwrap_or(0)
    }

    /// Number of logical shards in the ring.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The ring's global dataset shape, learned at handshake.
    pub fn shape(&self) -> (usize, usize) {
        (self.n_total, self.d)
    }

    /// Whether this client was connected in degraded mode.
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Ring-wide high-water mark of concurrently in-flight sub-waves on
    /// any single connection — the client-side witness that waves
    /// actually multiplex (`bench pull` asserts ≥ 2 on its rung).
    pub fn max_inflight_per_conn(&self) -> u64 {
        self.max_inflight.load(Ordering::SeqCst)
    }

    fn fresh_wave_id(&self) -> u64 {
        self.next_wave.fetch_add(1, Ordering::SeqCst)
    }

    fn submit_to_shard(&self, shard: usize, wave_id: u64,
                       payload: Vec<u8>, deadline: Option<Instant>)
                       -> SubWave {
        SubWave::submit(self.shards[shard].clone(), wave_id, payload,
                        deadline)
    }

    /// Is shard `i` reachable right now? One tagged `Stats` round-trip
    /// on the live connection (a dead peer's socket looks open until
    /// I/O touches it), falling back to a backoff-respecting reconnect.
    /// The probe honors the caller's query budget, so a coverage check
    /// against a blackholed shard costs at most the remaining budget.
    fn shard_live(&self, i: usize, deadline: Option<Instant>) -> bool {
        let wid = self.fresh_wave_id();
        let mut payload = Vec::new();
        wire::encode_stats(&mut payload, wid);
        let sub = self.submit_to_shard(i, wid, payload, deadline);
        matches!(sub.wait(), Ok(Message::StatsReply { .. }))
    }

    /// In degraded mode, the global row ranges whose shards currently
    /// have a live (or immediately reconnectable, backoff permitting)
    /// replica; `None` when every shard is reachable, or when degraded
    /// mode is off (then a dead shard panics the wave instead). Shards
    /// are probed concurrently, so a healthy degraded-mode ring pays
    /// ~one `Stats` round-trip of latency per coverage query, not S.
    pub fn coverage(&self) -> Option<Coverage> {
        self.coverage_deadline(None)
    }

    /// [`RingClient::coverage`] with the probes bounded by a query
    /// budget — the deadline-threading engine path. A probe cut off by
    /// the budget counts its shard as down, which is the conservative
    /// answer (the caller is about to answer degraded; claiming rows it
    /// could not verify would be wrong).
    pub fn coverage_deadline(&self, deadline: Option<Instant>)
                             -> Option<Coverage> {
        if !self.degraded {
            return None;
        }
        let s = self.shards.len();
        let oks: Vec<bool> = if s <= 1 {
            (0..s).map(|i| self.shard_live(i, deadline)).collect()
        } else {
            std::thread::scope(|sc| {
                let handles: Vec<_> = (0..s)
                    .map(|i| {
                        sc.spawn(move || self.shard_live(i, deadline))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or(false))
                    .collect()
            })
        };
        let mut live = Vec::new();
        let mut full = true;
        for (i, ok) in oks.into_iter().enumerate() {
            let (a, b) = shard_range(i, self.n_total, s);
            if a == b {
                continue; // a zero-row shard loses nothing when it dies
            }
            if ok {
                live.push((a as u32, b as u32));
            } else {
                full = false;
            }
        }
        if full {
            None
        } else {
            Some(Coverage { live, rows_total: self.n_total })
        }
    }
}

/// Kill every live connection of `shards` and join the demux readers —
/// shared by `Drop` and the failed-construction path of
/// [`RingClient::connect_opts`].
fn shutdown_shards(shards: &[Arc<ShardState>],
                   readers: &Mutex<Vec<JoinHandle<()>>>) {
    for st in shards {
        let conns: Vec<Arc<Conn>> = {
            let mut inner = st.inner.lock().unwrap();
            inner.conns.iter_mut().filter_map(|c| c.take()).collect()
        };
        for c in conns {
            c.kill("ring client closed");
        }
    }
    for h in readers.lock().unwrap().drain(..) {
        let _ = h.join();
    }
}

impl Drop for RingClient {
    fn drop(&mut self) {
        shutdown_shards(&self.shards, &self.readers);
    }
}

/// Dial one endpoint, honoring `timeout` during the connect phase too —
/// a blackholed host (no RST) must not strand the caller for the OS SYN
/// retry window.
fn connect_endpoint(ep: &str, timeout: Option<Duration>)
                    -> io::Result<TcpStream> {
    let Some(t) = timeout else {
        return TcpStream::connect(ep);
    };
    let addrs: Vec<SocketAddr> = ep.to_socket_addrs()?.collect();
    let mut last_err = None;
    for addr in addrs {
        match TcpStream::connect_timeout(&addr, t) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidInput,
                       "endpoint resolved to no addresses")
    }))
}

/// Connection options for [`RingClient::connect_opts`] /
/// [`RemoteEngine::connect_opts`].
#[derive(Clone, Copy, Debug)]
pub struct RemoteOptions {
    /// per-connection I/O timeout, applied to connects, writes and
    /// per-wave reply waits (`None` = block forever; tests use short
    /// timeouts)
    pub timeout: Option<Duration>,
    /// opt into degraded answers: with every replica of a shard dead,
    /// [`RingClient::coverage`] reports the surviving rows instead of
    /// waves panicking (`[engine] degraded` / `--degraded`)
    pub degraded: bool,
    /// per-endpoint backoff schedule for the failover blacklist
    pub retry: RetryPolicy,
    /// refuse endpoints whose handshake reports a different placement
    /// epoch (`None` = adopt whatever single epoch the ring reports).
    /// The reshard path connects the new ring with the new epoch
    /// pinned, so a leftover old-placement endpoint can never sneak
    /// into the new connection set.
    pub expect_epoch: Option<u64>,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            timeout: Some(DEFAULT_IO_TIMEOUT),
            degraded: false,
            retry: RetryPolicy::default(),
            expect_epoch: None,
        }
    }
}

// ---------------------------------------------------------------------
// remote engine (a PullEngine over the shared ring client)
// ---------------------------------------------------------------------

enum WaveKind {
    Sums,
    Dists,
}

/// One wave this engine has submitted but not yet completed: its
/// partition plan (owning the scatter slots) and the per-shard
/// in-flight sub-waves.
struct InflightWave {
    partition: WavePartition,
    kind: WaveKind,
    total: usize,
    subs: Vec<Option<SubWave>>,
}

/// Networked [`PullEngine`] over a shared [`RingClient`] — see the
/// module docs for the ring contract, determinism, failover and
/// degraded-mode semantics. Cheap to construct per worker
/// ([`RemoteEngine::from_client`]): the connection set lives in the
/// shared client, so every worker's waves interleave on one socket per
/// shard. The `submit_*`/`complete_*` half of the engine API is
/// genuinely pipelined here: sub-waves are on the wire when submit
/// returns and any number of waves may be in flight concurrently.
pub struct RemoteEngine {
    client: Arc<RingClient>,
    /// recycled wave planners (one per concurrently in-flight wave)
    spare_parts: Vec<WavePartition>,
    inflight: HashMap<u64, InflightWave>,
    next_key: u64,
    /// query-budget deadline applied to every subsequent wave's waits
    /// (`PullEngine::set_deadline`); `None` = I/O timeout only
    deadline: Option<Instant>,
}

impl RemoteEngine {
    /// Connect a fresh [`RingClient`] to a ring given one spec per
    /// shard (replicas separated by `|` within a spec) and wrap it.
    /// Defaults: [`DEFAULT_IO_TIMEOUT`], degraded off.
    pub fn connect(endpoints: &[String]) -> Result<RemoteEngine, String> {
        Ok(Self::from_client(Arc::new(RingClient::connect(endpoints)?)))
    }

    /// [`RemoteEngine::connect`] with an explicit per-connection I/O
    /// timeout (`None` = block forever; tests use short timeouts).
    pub fn connect_with_timeout(endpoints: &[String],
                                timeout: Option<Duration>)
                                -> Result<RemoteEngine, String> {
        Self::connect_opts(&PlacementMap::parse(endpoints)?,
                           RemoteOptions { timeout,
                                           ..RemoteOptions::default() })
    }

    /// Connect a fresh [`RingClient`] with explicit options and wrap it.
    pub fn connect_opts(placement: &PlacementMap, opts: RemoteOptions)
                        -> Result<RemoteEngine, String> {
        Ok(Self::from_client(Arc::new(RingClient::connect_opts(placement,
                                                               opts)?)))
    }

    /// Wrap a shared ring client — the per-worker constructor: every
    /// engine built from the same `Arc` multiplexes its waves onto the
    /// same one-connection-per-shard set.
    pub fn from_client(client: Arc<RingClient>) -> RemoteEngine {
        RemoteEngine {
            client,
            spare_parts: Vec::new(),
            inflight: HashMap::new(),
            next_key: 1,
            deadline: None,
        }
    }

    /// The shared ring client this engine submits through.
    pub fn client(&self) -> &Arc<RingClient> {
        &self.client
    }

    /// Number of logical shards in the ring.
    pub fn n_shards(&self) -> usize {
        self.client.n_shards()
    }

    /// The ring's global dataset shape, learned at handshake.
    pub fn shape(&self) -> (usize, usize) {
        self.client.shape()
    }

    fn check_dataset(&self, data: &DenseDataset) {
        let (n_total, d) = self.client.shape();
        assert!(
            data.n == n_total && data.d == d,
            "remote ring serves n={} d={} but this wave's dataset is n={} \
             d={} — every shard server must load the same dataset as the \
             coordinator",
            n_total, d, data.n, data.d
        );
    }

    fn take_partition(&mut self) -> WavePartition {
        self.spare_parts
            .pop()
            .unwrap_or_else(|| WavePartition::new(self.client.n_shards()))
    }

    /// Fan the planned wave's per-shard payloads onto the wire and park
    /// the in-flight state under a fresh ticket key. `encode` builds
    /// shard `i`'s payload for the given wave id.
    fn stage_wave<F>(&mut self, partition: WavePartition, kind: WaveKind,
                     total: usize, mut encode: F) -> WaveTicket
    where
        F: FnMut(&WavePartition, usize, u64) -> Vec<u8>,
    {
        let s = self.client.n_shards();
        let mut subs = Vec::with_capacity(s);
        for i in 0..s {
            if partition.wave(i).rows.is_empty() {
                subs.push(None);
                continue;
            }
            let wid = self.client.fresh_wave_id();
            let payload = encode(&partition, i, wid);
            subs.push(Some(self.client.submit_to_shard(i, wid, payload,
                                                       self.deadline)));
        }
        let key = self.next_key;
        self.next_key += 1;
        self.inflight
            .insert(key, InflightWave { partition, kind, total, subs });
        WaveTicket::deferred(key)
    }

    fn take_inflight(&mut self, ticket: &WaveTicket) -> InflightWave {
        self.inflight
            .remove(&ticket.key())
            .expect("unknown or already-completed remote WaveTicket")
    }
}

impl PullEngine for RemoteEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let t = self.submit_partial_sums(data, query, rows, coord_ids,
                                         metric);
        self.complete_sums(t, out_sum, out_sq);
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        let t = self.submit_exact_dists(data, query, rows, metric);
        self.complete_dists(t, out);
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let t = self.submit_pull_batch(data, reqs, metric);
        self.complete_sums(t, out_sum, out_sq);
    }

    fn submit_partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        self.check_dataset(data);
        let mut partition = self.take_partition();
        partition.split_rows(data.n, rows);
        self.stage_wave(partition, WaveKind::Sums, rows.len(),
                        |part, i, wid| {
            let mut payload = Vec::new();
            wire::encode_partial_sums(&mut payload, wid, metric, query,
                                      &part.wave(i).rows, coord_ids);
            payload
        })
    }

    fn submit_exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
    ) -> WaveTicket {
        self.check_dataset(data);
        let mut partition = self.take_partition();
        partition.split_rows(data.n, rows);
        self.stage_wave(partition, WaveKind::Dists, rows.len(),
                        |part, i, wid| {
            let mut payload = Vec::new();
            wire::encode_exact_dists(&mut payload, wid, metric, query,
                                     &part.wave(i).rows);
            payload
        })
    }

    fn submit_pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
    ) -> WaveTicket {
        self.check_dataset(data);
        let mut partition = self.take_partition();
        let total = partition.split_batch(data.n, reqs);
        self.stage_wave(partition, WaveKind::Sums, total,
                        |part, i, wid| {
            let sub: Vec<PullRequest> =
                part.wave(i).subrequests(reqs).collect();
            let mut payload = Vec::new();
            wire::encode_pull_batch(&mut payload, wid, metric, &sub);
            payload
        })
    }

    fn complete_sums(&mut self, mut ticket: WaveTicket,
                     out_sum: &mut Vec<f64>, out_sq: &mut Vec<f64>) {
        if let Some((s, q)) = ticket.take_ready() {
            *out_sum = s;
            *out_sq = q;
            return;
        }
        let InflightWave { partition, kind, total, subs } =
            self.take_inflight(&ticket);
        assert!(matches!(kind, WaveKind::Sums),
                "complete_sums on an exact-dists ticket");
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        for (i, sub) in subs.into_iter().enumerate() {
            let Some(sub) = sub else { continue };
            let wave = partition.wave(i);
            match sub.wait() {
                Ok(Message::Sums { sum, sq, .. }) => {
                    if sum.len() != wave.rows.len() {
                        panic!(
                            "remote pull wave failed: shard {i}: {} \
                             results for {} requested rows",
                            sum.len(),
                            wave.rows.len()
                        );
                    }
                    wave.scatter(&sum, out_sum);
                    wave.scatter(&sq, out_sq);
                }
                Ok(other) => panic!(
                    "remote pull wave failed: shard {i}: unexpected {} \
                     reply", other.kind()),
                Err(e) => panic!("remote pull wave failed: {e}"),
            }
        }
        self.spare_parts.push(partition);
    }

    fn complete_dists(&mut self, mut ticket: WaveTicket,
                      out: &mut Vec<f64>) {
        if let Some((vals, _)) = ticket.take_ready() {
            *out = vals;
            return;
        }
        let InflightWave { partition, kind, total, subs } =
            self.take_inflight(&ticket);
        assert!(matches!(kind, WaveKind::Dists),
                "complete_dists on a sums ticket");
        out.clear();
        out.resize(total, 0.0);
        for (i, sub) in subs.into_iter().enumerate() {
            let Some(sub) = sub else { continue };
            let wave = partition.wave(i);
            match sub.wait() {
                Ok(Message::Dists { vals, .. }) => {
                    if vals.len() != wave.rows.len() {
                        panic!(
                            "remote exact wave failed: shard {i}: {} \
                             results for {} requested rows",
                            vals.len(),
                            wave.rows.len()
                        );
                    }
                    wave.scatter(&vals, out);
                }
                Ok(other) => panic!(
                    "remote exact wave failed: shard {i}: unexpected {} \
                     reply", other.kind()),
                Err(e) => panic!("remote exact wave failed: {e}"),
            }
        }
        self.spare_parts.push(partition);
    }

    fn pipelined(&self) -> bool {
        true
    }

    fn set_deadline(&mut self, deadline: Option<Instant>) {
        self.deadline = deadline;
        // hygiene: a query that panicked out of its batch driver can
        // leave waves parked here; the next query must not inherit
        // them (their sub-waves carry the *old* budget). Reclaim the
        // planners, drop the sub-waves — a late reply just clears its
        // pending slot when the demux reader routes it.
        for (_, w) in self.inflight.drain() {
            self.spare_parts.push(w.partition);
        }
    }

    fn abandon_wave(&mut self, ticket: WaveTicket) {
        // discard a speculative wave that missed: reclaim the planner,
        // drop the sub-waves without waiting on them. `SubWave::wait` is
        // where failover attempts and deadline budget are spent, so an
        // abandoned wave consumes neither; the shard's late reply just
        // clears its pending demux slot when the reader routes it.
        if let Some(w) = self.inflight.remove(&ticket.key()) {
            self.spare_parts.push(w.partition);
        }
    }

    fn coverage(&mut self) -> Option<Coverage> {
        self.client.coverage_deadline(self.deadline)
    }

    fn name(&self) -> &'static str {
        "remote"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn raw_round_trip(stream: &mut TcpStream, payload: &[u8]) -> Message {
        wire::write_frame(stream, payload).unwrap();
        let mut buf = Vec::new();
        wire::read_frame(stream, &mut buf).unwrap();
        Message::decode(&buf).unwrap()
    }

    #[test]
    fn handshake_reports_shape_hash_and_shutdown_stops_the_server() {
        let ds = synthetic::gaussian_iid(10, 8, 1);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 2)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf, 5, wire::PROTOCOL_VERSION);
        match raw_round_trip(&mut stream, &buf) {
            Message::HelloAck { wave_id, version, n_total, d, row_start,
                                row_end, data_hash, epoch } => {
                assert_eq!(wave_id, 5, "reply must echo the request tag");
                assert_eq!(version, wire::PROTOCOL_VERSION);
                assert_eq!((n_total, d), (10, 8));
                assert_eq!((row_start, row_end), (5, 10));
                assert_eq!(epoch, 0,
                           "a never-resharded server serves epoch 0");
                // fingerprint matches a local recomputation of the slice
                let (a, b) = shard_range(1, ds.n, 2);
                let mut rows = Vec::new();
                for r in a..b {
                    rows.extend_from_slice(ds.row(r));
                }
                let local = DenseDataset::new(b - a, ds.d, rows);
                assert_eq!(data_hash,
                           wire::dataset_fingerprint(ds.n, a, &local));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        // a mismatched version is rejected with a clean error
        wire::encode_hello(&mut buf, 6, 999);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { wave_id, msg } => {
                assert_eq!(wave_id, 6);
                assert!(msg.contains("version"), "got: {msg}");
            }
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_shutdown(&mut buf, 7);
        assert_eq!(raw_round_trip(&mut stream, &buf),
                   Message::Ack { wave_id: 7 });
        assert!(srv.shutdown_requested());
    }

    #[test]
    fn v1_clients_get_a_clean_legacy_version_error() {
        let ds = synthetic::gaussian_iid(6, 4, 2);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 1)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        // a v1 Hello: bare opcode 1, no tag — exactly what a PR 3/4
        // client would send
        wire::write_frame(&mut stream, &[1u8]).unwrap();
        let mut buf = Vec::new();
        wire::read_frame(&mut stream, &mut buf).unwrap();
        // the reply is v1-framed (op 8 | u32 len | msg) so the old
        // client's decoder parses it as a clean Error
        assert_eq!(buf[0], 8, "legacy error must use the v1 opcode");
        let len =
            u32::from_le_bytes(buf[1..5].try_into().unwrap()) as usize;
        let msg = String::from_utf8_lossy(&buf[5..5 + len]);
        assert!(msg.contains("version mismatch"), "got: {msg}");
        // and the server closes the connection afterwards
        assert!(wire::read_frame(&mut stream, &mut buf).is_err(),
                "server must disconnect a v1 peer after the error");
        drop(srv);
    }

    #[test]
    fn client_rejects_v1_servers_with_a_version_error() {
        // a fake v1 server: answers any frame with a v1-framed Error,
        // which is what a real PR 4 server does for unknown opcodes
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            wire::read_frame(&mut s, &mut buf).unwrap();
            let mut out = Vec::new();
            wire::encode_legacy_error(&mut out, "bad frame: unknown \
                                                 opcode 101");
            wire::write_frame(&mut s, &out).unwrap();
        });
        let err = RemoteEngine::connect_with_timeout(
            &[ep], Some(Duration::from_secs(5))).unwrap_err();
        assert!(err.contains("version mismatch"), "got: {err}");
        h.join().unwrap();
    }

    #[test]
    fn v2_clients_get_a_clean_version_error_in_v2_framing() {
        // a v2 client's Hello is byte-identical to a v3 one except for
        // the version field, and the Error frame the gate answers with
        // kept its v2 opcode and layout — so the old client decodes a
        // clean version error in its own framing, mirroring the v1
        // rejection path one protocol generation later
        let ds = synthetic::gaussian_iid(6, 4, 2);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 1)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf, 3, 2);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { wave_id, msg } => {
                assert_eq!(wave_id, 3, "error must carry the wave tag \
                                        a v2 peer demultiplexes on");
                assert!(msg.contains("version mismatch"), "got: {msg}");
                assert!(msg.contains("v3"), "got: {msg}");
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn client_rejects_v2_servers_with_a_version_error() {
        // a fake v2 server: answers the handshake with a retired-opcode
        // (102) HelloAck in the old epochless layout — exactly what a
        // real PR 5–8 server sends. The v3 client must refuse it with
        // a version error, not misparse the epochless payload.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            wire::read_frame(&mut s, &mut buf).unwrap();
            let wid = wire::peek_wave_id(&buf);
            let mut out = vec![102u8];
            for v in [wid, 2, 8, 4, 0, 8] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out.extend_from_slice(&0xfeedu64.to_le_bytes());
            wire::write_frame(&mut s, &out).unwrap();
        });
        let err = RemoteEngine::connect_with_timeout(
            &[ep], Some(Duration::from_secs(5))).unwrap_err();
        assert!(err.contains("version mismatch"), "got: {err}");
        assert!(err.contains("v2"), "got: {err}");
        h.join().unwrap();
    }

    #[test]
    fn staging_transfer_installs_a_fingerprint_verified_server() {
        let ds = synthetic::gaussian_iid(20, 6, 9);
        let t = Some(Duration::from_secs(5));
        let stg = ShardServer::start_staging("127.0.0.1:0",
                                             KernelChoice::Auto, t)
            .unwrap();
        let ep = stg.endpoint();
        // before the transfer: probes answer a clean staging error,
        // never a hang or a crash
        let err = endpoint_stats(&ep, t).unwrap_err();
        assert!(err.contains("staging"), "got: {err}");
        // rows must be preceded by a begin
        {
            let mut stream = TcpStream::connect(stg.addr).unwrap();
            let mut buf = Vec::new();
            wire::encode_transfer_rows(&mut buf, 1, 0, &[1.0; 6]);
            match raw_round_trip(&mut stream, &buf) {
                Message::Error { msg, .. } => {
                    assert!(msg.contains("transfer_begin"), "got: {msg}")
                }
                other => panic!("unexpected {}", other.kind()),
            }
            // a begin that contradicts the canonical partition is
            // refused too
            wire::encode_transfer_begin(&mut buf, 2, 1, 2, 20, 6, 0, 10,
                                        7);
            match raw_round_trip(&mut stream, &buf) {
                Message::Error { msg, .. } => {
                    assert!(msg.contains("partition"), "got: {msg}")
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        // the real transfer: shard 1 of 2 at epoch 7
        let fp = transfer_shard(&ep, &ds, 1, 2, 7, t).unwrap();
        let st = endpoint_stats(&ep, t).unwrap();
        assert_eq!((st.shard, st.of), (1, 2));
        assert_eq!((st.row_start, st.row_end), shard_range(1, 20, 2));
        assert_eq!(st.data_hash, fp);
        assert_eq!(st.epoch, 7);
        // the installed placement is immutable — a second transfer is
        // refused like on any serving server
        let err = transfer_shard(&ep, &ds, 1, 2, 8, t).unwrap_err();
        assert!(err.contains("staging server"), "got: {err}");
        drop(stg);
    }

    #[test]
    fn reshard_to_populates_a_ring_that_matches_solo_bitwise() {
        let ds = synthetic::gaussian_iid(24, 8, 13);
        let t = Some(Duration::from_secs(5));
        let stg: Vec<ShardServer> = (0..2)
            .map(|_| {
                ShardServer::start_staging("127.0.0.1:0",
                                           KernelChoice::Auto, t)
                    .unwrap()
            })
            .collect();
        let eps: Vec<String> =
            stg.iter().map(|s| s.endpoint()).collect();
        let placement = PlacementMap::parse(&eps).unwrap();
        let fps = reshard_to(&ds, &placement, 3, t).unwrap();
        assert_eq!(fps.len(), 2);
        // connect with the new epoch pinned: waves match solo bitwise
        let client = Arc::new(RingClient::connect_opts(
            &placement,
            RemoteOptions { timeout: t,
                            expect_epoch: Some(3),
                            ..RemoteOptions::default() })
            .unwrap());
        assert_eq!(client.epoch(), 3);
        let mut eng = RemoteEngine::from_client(client);
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (0..24).collect();
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds, &q, &rows, &[0, 3], Metric::L2Sq, &mut s,
                         &mut sq);
        let mut solo = NativeEngine::default();
        let (mut ws, mut wq) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &q, &rows, &[0, 3], Metric::L2Sq,
                          &mut ws, &mut wq);
        assert_eq!(s, ws);
        assert_eq!(sq, wq);
        // pinning the wrong epoch refuses the ring
        let err = RingClient::connect_opts(
            &placement,
            RemoteOptions { timeout: t,
                            expect_epoch: Some(2),
                            ..RemoteOptions::default() })
            .map(|_| ())
            .unwrap_err();
        assert!(err.contains("expects epoch 2"), "got: {err}");
    }

    #[test]
    fn mixed_epoch_rings_are_refused() {
        // shard 0 at epoch 0 (plain startup), shard 1 at epoch 5 (via
        // transfer): one placement, two epochs — the client must refuse
        // rather than serve a placement that is half old, half new
        let ds = synthetic::gaussian_iid(10, 4, 21);
        let t = Some(Duration::from_secs(5));
        let s0 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let stg = ShardServer::start_staging("127.0.0.1:0",
                                             KernelChoice::Auto, t)
            .unwrap();
        transfer_shard(&stg.endpoint(), &ds, 1, 2, 5, t).unwrap();
        let eps = vec![s0.endpoint(), stg.endpoint()];
        let err = RemoteEngine::connect_with_timeout(&eps, t)
            .map(|_| ())
            .unwrap_err();
        assert!(err.contains("epoch"), "got: {err}");
        assert!(err.contains("diverges"), "got: {err}");
    }

    #[test]
    fn stats_op_reports_identity_range_hash_and_connections() {
        let ds = synthetic::gaussian_iid(10, 4, 8);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 3)
            .unwrap(); // owns rows [3, 6)
        let stats = endpoint_stats(&srv.endpoint(),
                                   Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(stats.shard, 1);
        assert_eq!(stats.of, 3);
        assert_eq!((stats.n_total, stats.d), (10, 4));
        assert_eq!((stats.row_start, stats.row_end), (3, 6));
        assert!(stats.live_conns >= 1, "probe connection must be counted");
        assert_ne!(stats.data_hash, 0);
        // a replica serving the same slice reports the same fingerprint
        let srv2 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 3)
            .unwrap();
        let stats2 = endpoint_stats(&srv2.endpoint(),
                                    Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(stats.data_hash, stats2.data_hash);
        // a dead endpoint reports an error, not a hang
        let dead = srv.endpoint();
        drop(srv);
        let err = endpoint_stats(&dead, Some(Duration::from_secs(5)))
            .unwrap_err();
        assert!(err.contains(&dead), "got: {err}");
    }

    #[test]
    fn server_answers_errors_for_invalid_requests() {
        let ds = synthetic::gaussian_iid(12, 6, 2);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 3)
            .unwrap(); // owns rows [0, 4)
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let q = vec![0.0f32; 6];
        let mut buf = Vec::new();
        // out-of-range row
        wire::encode_partial_sums(&mut buf, 11, Metric::L2Sq, &q, &[7],
                                  &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { wave_id, msg } => {
                assert_eq!(wave_id, 11, "error must carry the wave tag");
                assert!(msg.contains("row 7"));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        // wrong query dim
        wire::encode_exact_dists(&mut buf, 12, Metric::L1, &[1.0], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg, .. } => assert!(msg.contains("dim")),
            other => panic!("unexpected {}", other.kind()),
        }
        // coordinate out of range
        wire::encode_partial_sums(&mut buf, 13, Metric::L1, &q, &[1],
                                  &[99]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg, .. } => {
                assert!(msg.contains("coordinate"))
            }
            other => panic!("unexpected {}", other.kind()),
        }
        // garbage payload (not a v1 opcode): error reply, connection
        // stays usable
        match raw_round_trip(&mut stream, &[42, 1, 2]) {
            Message::Error { msg, .. } => {
                assert!(msg.contains("bad frame"))
            }
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_partial_sums(&mut buf, 14, Metric::L1, &q, &[1],
                                  &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Sums { wave_id, sum, sq } => {
                assert_eq!(wave_id, 14);
                assert_eq!(sum.len(), 1);
                assert_eq!(sq.len(), 1);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn server_computes_tagged_waves_concurrently_and_out_of_order() {
        // submit a LARGE wave then a tiny one on the same connection
        // without reading; the tiny one finishes first, so the replies
        // arrive out of submission order, routed by tag
        let n = 192;
        let d = 64;
        let ds = synthetic::gaussian_iid(n, d, 33);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 1)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let q = ds.row_vec(0);
        let big_rows: Vec<u32> = (0..n as u32).cycle().take(64 * n)
            .collect();
        let big_coords: Vec<u32> = (0..d as u32).cycle().take(512)
            .collect();
        let mut big = Vec::new();
        wire::encode_partial_sums(&mut big, 100, Metric::L2Sq, &q,
                                  &big_rows, &big_coords);
        let mut small = Vec::new();
        wire::encode_partial_sums(&mut small, 101, Metric::L2Sq, &q,
                                  &[3], &[0]);
        wire::write_frame(&mut stream, &big).unwrap();
        wire::write_frame(&mut stream, &small).unwrap();
        let mut buf = Vec::new();
        let mut got = std::collections::HashMap::new();
        for _ in 0..2 {
            wire::read_frame(&mut stream, &mut buf).unwrap();
            match Message::decode(&buf).unwrap() {
                Message::Sums { wave_id, sum, .. } => {
                    got.insert(wave_id, sum.len());
                }
                other => panic!("unexpected {}", other.kind()),
            }
        }
        assert_eq!(got.get(&100), Some(&big_rows.len()));
        assert_eq!(got.get(&101), Some(&1));
        // the server witnessed >= 2 concurrent waves on one connection
        let stats = endpoint_stats(&srv.endpoint(),
                                   Some(Duration::from_secs(5)))
            .unwrap();
        assert!(stats.max_conn_waves >= 2,
                "server saw max {} concurrent waves",
                stats.max_conn_waves);
    }

    #[test]
    fn connect_rejects_a_ring_that_does_not_tile_the_dataset() {
        let ds = synthetic::gaussian_iid(9, 4, 3);
        // both servers claim shard 0 of 2 — the second endpoint's range
        // does not match the partition's assignment for index 1
        let s0 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let s1 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s1.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("partition"), "got: {err}");
        // mismatched dataset shapes are rejected too
        let other = synthetic::gaussian_iid(7, 4, 4);
        let s2 = ShardServer::start_shard_of("127.0.0.1:0", &other, 1, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s2.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("one dataset") || err.contains("partition"),
                "got: {err}");
    }

    #[test]
    fn divergent_replica_fingerprints_are_rejected() {
        // two "replicas" of shard 0 of 1 serving the same shape but
        // different bytes: the first connects, the second must be
        // refused by the fingerprint check when failover reaches it
        let ds_a = synthetic::gaussian_iid(8, 4, 11);
        let ds_b = synthetic::gaussian_iid(8, 4, 12); // diverged content
        let sa = ShardServer::start_shard_of("127.0.0.1:0", &ds_a, 0, 1)
            .unwrap();
        let sb = ShardServer::start_shard_of("127.0.0.1:0", &ds_b, 0, 1)
            .unwrap();
        let spec = vec![format!("{}|{}", sa.endpoint(), sb.endpoint())];
        let mut eng = RemoteEngine::connect_with_timeout(
            &spec, Some(Duration::from_secs(5))).unwrap();
        // healthy primary: fine
        let q = ds_a.row_vec(0);
        let rows: Vec<u32> = (0..8).collect();
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds_a, &q, &rows, &[0, 1], Metric::L2Sq, &mut s,
                         &mut sq);
        // kill the primary: failover reaches the divergent replica,
        // whose handshake is refused — the wave fails with the
        // fingerprint error rather than silently mixing datasets
        drop(sa);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                eng.partial_sums(&ds_a, &q, &rows, &[0, 1], Metric::L2Sq,
                                 &mut s, &mut sq);
            }))
            .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("fingerprint"), "got: {msg}");
        drop(sb);
    }

    #[test]
    fn connect_prefers_earlier_replicas_but_tolerates_dead_ones() {
        let ds = synthetic::gaussian_iid(8, 4, 6);
        let (ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        // reserve a port that is then closed: a guaranteed-dead endpoint
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        // shard 0's primary is dead — connect must fall through to the
        // live replica and waves must match the healthy ring bitwise
        let specs = vec![format!("{dead}|{}", eps[0]), eps[1].clone()];
        let mut eng = RemoteEngine::connect_with_timeout(
            &specs, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(eng.shape(), (8, 4));
        let mut healthy = RemoteEngine::connect_with_timeout(
            &eps, Some(Duration::from_secs(5))).unwrap();
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (0..8).collect();
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds, &q, &rows, &[0, 2], Metric::L2Sq, &mut s1,
                         &mut q1);
        healthy.partial_sums(&ds, &q, &rows, &[0, 2], Metric::L2Sq,
                             &mut s2, &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
        // degraded connect with only dead endpoints still fails: the
        // dataset shape cannot be learned from nothing
        let all_dead = vec![dead.clone(), dead];
        let err = RingClient::connect_opts(
            &PlacementMap::parse(&all_dead).unwrap(),
            RemoteOptions { timeout: Some(Duration::from_millis(500)),
                            degraded: true,
                            ..RemoteOptions::default() })
            .map(|_| ())
            .unwrap_err();
        assert!(err.contains("reachable"), "got: {err}");
        drop(ring);
    }

    #[test]
    fn wave_against_a_mismatched_dataset_panics_with_context() {
        let ds = synthetic::gaussian_iid(8, 4, 5);
        let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let mut eng = RemoteEngine::connect(&eps).unwrap();
        assert_eq!(eng.shape(), (8, 4));
        assert_eq!(eng.n_shards(), 2);
        assert_eq!(eng.name(), "remote");
        assert!(eng.pipelined());
        assert_eq!(eng.coverage(), None, "degraded off: never degraded");
        let wrong = synthetic::gaussian_iid(9, 4, 6);
        let q = wrong.row_vec(0);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                eng.partial_sums(&wrong, &q, &[0], &[0], Metric::L2Sq,
                                 &mut s, &mut sq);
            }))
            .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("same dataset"), "got: {msg}");
    }

    #[test]
    fn shared_client_multiplexes_concurrent_waves_on_one_connection() {
        // two waves submitted before either completes: both pending on
        // the same per-shard connection, completed in reverse order,
        // bitwise identical to the solo engine
        let ds = synthetic::gaussian_iid(24, 16, 44);
        let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let client = Arc::new(RingClient::connect(&eps).unwrap());
        let mut eng = RemoteEngine::from_client(client.clone());
        let q1 = ds.row_vec(0);
        let q2 = ds.row_vec(1);
        // wave 1 is large (many repeated rows x many coords) so its
        // server-side compute comfortably outlasts the microseconds it
        // takes to submit wave 2 — the two are then reliably pending on
        // the same connection at once
        let rows: Vec<u32> =
            (0..24u32).cycle().take(24 * 256).collect();
        let coords: Vec<u32> =
            (0..16u32).cycle().take(512).collect();
        let t1 = eng.submit_partial_sums(&ds, &q1, &rows, &coords,
                                         Metric::L2Sq);
        let t2 = eng.submit_partial_sums(&ds, &q2, &rows, &coords,
                                         Metric::L1);
        // both waves are on the wire now — complete in reverse order
        let (mut s2, mut sq2) = (Vec::new(), Vec::new());
        eng.complete_sums(t2, &mut s2, &mut sq2);
        let (mut s1, mut sq1) = (Vec::new(), Vec::new());
        eng.complete_sums(t1, &mut s1, &mut sq1);
        let mut solo = NativeEngine::default();
        let (mut w1, mut wq1) = (Vec::new(), Vec::new());
        let (mut w2, mut wq2) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &q1, &rows, &coords, Metric::L2Sq, &mut w1,
                          &mut wq1);
        solo.partial_sums(&ds, &q2, &rows, &coords, Metric::L1, &mut w2,
                          &mut wq2);
        assert_eq!(s1, w1);
        assert_eq!(sq1, wq1);
        assert_eq!(s2, w2);
        assert_eq!(sq2, wq2);
        assert!(client.max_inflight_per_conn() >= 2,
                "two submitted waves must overlap on one connection \
                 (high-water {})", client.max_inflight_per_conn());
        // a second engine over the same client shares the connections
        let mut eng2 = RemoteEngine::from_client(client.clone());
        let mut d1 = Vec::new();
        eng2.exact_dists(&ds, &q1, &rows, Metric::L2Sq, &mut d1);
        let mut w = Vec::new();
        solo.exact_dists(&ds, &q1, &rows, Metric::L2Sq, &mut w);
        assert_eq!(d1, w);
    }
}
