//! Network-distributed pull execution: fan engine waves over a
//! **replicated ring** of TCP shard servers, each owning a contiguous
//! row range of the dataset, with transparent failover between a
//! shard's replicas and an opt-in degraded mode when a whole shard is
//! unreachable.
//!
//! Two halves:
//!
//! * [`ShardServer`] — the `bmonn shard-serve` backend. It holds rows
//!   `[row_start, row_end)` of the global dataset and answers
//!   `partial_sums` / `exact_dists` / `pull_batch` waves over the
//!   length-prefixed binary protocol in [`crate::runtime::wire`],
//!   computing with a per-connection `NativeEngine`. Rows travel as
//!   global ids and are rebased locally; anything outside the owned
//!   range is answered with a wire `Error`, never a crash. A `Stats`
//!   frame (the health op) reports the server's shard identity, row
//!   range and live-connection count without touching the compute path.
//! * [`RemoteEngine`] — a [`PullEngine`] over a
//!   [`crate::runtime::placement::PlacementMap`]: each logical shard has
//!   an **ordered replica list** of endpoints and one live connection at
//!   a time. Every wave is split with the same
//!   [`crate::runtime::partition::WavePartition`] the in-process
//!   [`crate::runtime::sharded::ShardedEngine`] uses (one splitter,
//!   shared code), sub-waves fan out concurrently on scoped threads, and
//!   replies scatter back by slot — so remote output is **bitwise
//!   identical** to a single-threaded `NativeEngine` for any ring size
//!   (`tests/remote_parity.rs` pins this case-for-case against
//!   `tests/sharded_parity.rs`).
//!
//! **Ring contract.** Every replica of logical shard `i` of `S` must
//! serve exactly `shard_range(i, n, S)` of the same dataset;
//! [`RemoteEngine::connect_opts`] (and the failover path, lazily)
//! verifies this against each server's handshake and refuses a replica
//! that tiles the dataset any other way. The coordinator's dataset must
//! match the ring's (n, d) — a mismatched wave panics with a clear
//! message.
//!
//! **Failover.** An I/O error or corrupt reply on a sub-wave
//! blacklists the replica it came from (exponential backoff,
//! [`crate::runtime::placement::RetryPolicy`]); a wire `Error` reply
//! fails over without blacklisting (the connection is healthy — only
//! this request failed server-side). Either way the *same* sub-wave is
//! transparently re-issued to the shard's next live replica — each
//! endpoint is tried at most once per wave, so retries are bounded. Because every replica computes the same jobs with the same
//! kernel, a failed-over wave is bitwise identical to a healthy one:
//! killing any single endpoint of a replicated ring mid-stream yields
//! no query errors at all (`tests/remote_fault.rs`). A blacklisted
//! endpoint heals the moment a reconnect + handshake succeeds after its
//! backoff window, so a restarted server rejoins automatically.
//!
//! **Degraded mode.** With every replica of some shard dead, a wave
//! touching that shard's rows still panics (promptly — reads carry a
//! timeout) and the query server answers errors, exactly as in the
//! unreplicated ring. Opting in via `[engine] degraded = true` /
//! `--degraded` changes that: `RemoteEngine::coverage` then reports
//! the surviving row ranges, and the k-NN drivers
//! (`coordinator::knn`) answer **exact** top-k over the surviving rows
//! only, threading a `coverage` annotation (rows answered / n) through
//! [`crate::coordinator::knn::KnnResult`] and the query server's JSON
//! responses instead of erroring.

#![deny(missing_docs)]

use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::arms::{Coverage, PullEngine, PullRequest};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::native::NativeEngine;
use crate::runtime::partition::{shard_range, ShardWave, WavePartition};
use crate::runtime::placement::{EndpointState, PlacementMap, RetryPolicy};
use crate::runtime::wire::{self, Message, WireRequest};

/// Default per-connection read/write timeout: long enough for a big wave
/// to compute server-side, short enough that a wedged peer can never
/// strand a coordinator worker forever.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// shard server
// ---------------------------------------------------------------------

struct ShardShared {
    /// this shard's rows only (global rows `[row_start, row_start + n)`)
    local: DenseDataset,
    n_total: usize,
    row_start: usize,
    /// shard identity reported by the `Stats` health op
    shard: u64,
    of: u64,
    shutdown: AtomicBool,
    /// live connections (by id), shut down on stop so blocked I/O
    /// unblocks; each entry is removed when its handler thread exits, so
    /// a long-running server does not leak one fd per past connection
    conns: Mutex<Vec<(u64, TcpStream)>>,
}

/// A running shard server (see module docs). Stops on drop; a wire
/// `Shutdown` message also stops it (that is how a `shard-serve` CLI
/// process is told to exit remotely).
pub struct ShardServer {
    /// bound address (resolved, so `host:0` shows the ephemeral port)
    pub addr: SocketAddr,
    shared: Arc<ShardShared>,
    accept_handle: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Serve `local` (the rows `[row_start, row_start + local.n)` of a
    /// global `n_total`-row dataset) on `addr` (`"host:0"` picks an
    /// ephemeral port; see `self.addr`). `shard`/`of` are the identity
    /// the `Stats` health op reports — they do not affect computation
    /// (the row range is what waves validate against).
    pub fn start(addr: &str, local: DenseDataset, n_total: usize,
                 row_start: usize, shard: usize, of: usize)
                 -> io::Result<ShardServer> {
        assert!(row_start + local.n <= n_total,
                "shard rows [{row_start}, {}) exceed n_total={n_total}",
                row_start + local.n);
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(ShardShared {
            local,
            n_total,
            row_start,
            shard: shard as u64,
            of: of as u64,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bmonn-shard-serve".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .expect("spawn shard-serve accept thread");
        Ok(ShardServer { addr, shared, accept_handle: Some(accept_handle) })
    }

    /// Slice shard `shard` of `n_shards` out of `data` (the same
    /// floor-boundary partition `RemoteEngine` splits waves with) and
    /// serve it. Starting the same shard index on several machines
    /// creates replicas — any of them can serve the shard's sub-waves.
    pub fn start_shard_of(addr: &str, data: &DenseDataset, shard: usize,
                          n_shards: usize) -> io::Result<ShardServer> {
        let (a, b) = shard_range(shard, data.n, n_shards);
        let mut rows = Vec::with_capacity((b - a) * data.d);
        for r in a..b {
            rows.extend_from_slice(data.row(r));
        }
        Self::start(addr, DenseDataset::new(b - a, data.d, rows), data.n, a,
                    shard, n_shards)
    }

    /// `host:port` string of the bound address.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// True once a wire `Shutdown` was received (or `stop` was called) —
    /// the `shard-serve` CLI polls this to know when to exit.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown.load(Ordering::SeqCst)
    }

    /// Stop serving: kills live connections (blocked peer reads see EOF,
    /// like a process death would produce) and joins the accept thread.
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for (_, s) in self.shared.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start one in-process shard server per shard of `data` on loopback
/// ephemeral ports — the zero-infrastructure ring used by the parity
/// tests and the `bench pull` distributed rung.
pub fn spawn_loopback_ring(data: &DenseDataset, n_shards: usize)
                           -> Result<(Vec<ShardServer>, Vec<String>), String> {
    let mut servers = Vec::with_capacity(n_shards);
    let mut endpoints = Vec::with_capacity(n_shards);
    for i in 0..n_shards {
        let srv = ShardServer::start_shard_of("127.0.0.1:0", data, i,
                                              n_shards)
            .map_err(|e| format!("starting loopback shard {i}: {e}"))?;
        endpoints.push(srv.endpoint());
        servers.push(srv);
    }
    Ok((servers, endpoints))
}

fn accept_loop(listener: TcpListener, shared: Arc<ShardShared>) {
    let mut handles = Vec::new();
    let mut next_id = 0u64;
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let id = next_id;
                next_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().push((id, clone));
                }
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = serve_conn(stream, sh.clone());
                    // deregister so past connections don't pin fds
                    sh.conns.lock().unwrap().retain(|(c, _)| *c != id);
                }));
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    // a wire Shutdown set the flag without going through stop(): kill
    // the remaining connections so their blocked reads return, then reap
    for (_, s) in shared.conns.lock().unwrap().iter() {
        let _ = s.shutdown(Shutdown::Both);
    }
    for h in handles {
        let _ = h.join();
    }
}

/// One connection: framed request/reply until disconnect or `Shutdown`.
/// A panic in the compute path answers with a wire `Error` and a fresh
/// engine instead of dropping the connection.
fn serve_conn(mut stream: TcpStream, shared: Arc<ShardShared>)
              -> io::Result<()> {
    stream.set_nodelay(true)?;
    let mut engine = NativeEngine::default();
    let mut inbuf = Vec::new();
    let mut outbuf = Vec::new();
    let mut sums = Vec::new();
    let mut sqs = Vec::new();
    loop {
        if wire::read_frame(&mut stream, &mut inbuf).is_err() {
            return Ok(()); // disconnect, kill, or corrupt framing
        }
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                handle_frame(&shared, &mut engine, &inbuf, &mut outbuf,
                             &mut sums, &mut sqs)
            }));
        let quit = match outcome {
            Ok(q) => q,
            Err(_) => {
                engine = NativeEngine::default();
                wire::encode_error(&mut outbuf,
                                   "internal error: shard compute panicked");
                false
            }
        };
        wire::write_frame(&mut stream, &outbuf)?;
        if quit {
            return Ok(());
        }
    }
}

/// Decode + dispatch one request; returns true when the connection (and
/// server) should wind down.
fn handle_frame(sh: &ShardShared, engine: &mut NativeEngine, payload: &[u8],
                out: &mut Vec<u8>, sums: &mut Vec<f64>, sqs: &mut Vec<f64>)
                -> bool {
    let msg = match Message::decode(payload) {
        Err(e) => {
            wire::encode_error(out, &format!("bad frame: {e}"));
            return false;
        }
        Ok(m) => m,
    };
    match msg {
        Message::Hello => wire::encode_hello_ack(
            out,
            sh.n_total as u64,
            sh.local.d as u64,
            sh.row_start as u64,
            (sh.row_start + sh.local.n) as u64,
        ),
        Message::Stats => {
            // the health op: identity + load, computed without touching
            // the engine (safe to poll while waves are in flight)
            let live_conns = sh.conns.lock().unwrap().len() as u64;
            wire::encode_stats_reply(
                out,
                sh.shard,
                sh.of,
                sh.n_total as u64,
                sh.local.d as u64,
                sh.row_start as u64,
                (sh.row_start + sh.local.n) as u64,
                live_conns,
            );
        }
        Message::Shutdown => {
            sh.shutdown.store(true, Ordering::SeqCst);
            wire::encode_ack(out);
            return true;
        }
        Message::PartialSums { metric, query, rows, coord_ids } => {
            match validate_and_rebase(sh, &query, &rows, Some(&coord_ids)) {
                Err(e) => wire::encode_error(out, &e),
                Ok(local_rows) => {
                    engine.partial_sums(&sh.local, &query, &local_rows,
                                        &coord_ids, metric, sums, sqs);
                    wire::encode_sums(out, sums, sqs);
                }
            }
        }
        Message::ExactDists { metric, query, rows } => {
            match validate_and_rebase(sh, &query, &rows, None) {
                Err(e) => wire::encode_error(out, &e),
                Ok(local_rows) => {
                    engine.exact_dists(&sh.local, &query, &local_rows,
                                       metric, sums);
                    wire::encode_dists(out, sums);
                }
            }
        }
        Message::PullBatch { metric, reqs } => {
            match batch_compute(sh, engine, metric, &reqs, sums, sqs) {
                Err(e) => wire::encode_error(out, &e),
                Ok(()) => wire::encode_sums(out, sums, sqs),
            }
        }
        other => wire::encode_error(
            out,
            &format!("unexpected {} request", other.kind()),
        ),
    }
    false
}

/// Check dims/coords and map global row ids onto this shard's local
/// `[0, local.n)` range.
fn validate_and_rebase(sh: &ShardShared, query: &[f32], rows: &[u32],
                       coord_ids: Option<&[u32]>)
                       -> Result<Vec<u32>, String> {
    if query.len() != sh.local.d {
        return Err(format!("query dim {} != dataset dim {}", query.len(),
                           sh.local.d));
    }
    if let Some(cs) = coord_ids {
        if let Some(&j) = cs.iter().find(|&&j| j as usize >= sh.local.d) {
            return Err(format!("coordinate {j} out of range (d={})",
                               sh.local.d));
        }
    }
    let (a, b) = (sh.row_start, sh.row_start + sh.local.n);
    let mut local = Vec::with_capacity(rows.len());
    for &r in rows {
        let r = r as usize;
        if r < a || r >= b {
            return Err(format!(
                "row {r} outside this shard's range [{a}, {b})"));
        }
        local.push((r - a) as u32);
    }
    Ok(local)
}

/// Rebase and resolve a `PullBatch` wave with one engine pass; outputs
/// land in `sums`/`sqs` concatenated request-major, exactly as
/// [`PullEngine::pull_batch`] specifies.
fn batch_compute(sh: &ShardShared, engine: &mut NativeEngine,
                 metric: Metric, reqs: &[WireRequest], sums: &mut Vec<f64>,
                 sqs: &mut Vec<f64>) -> Result<(), String> {
    let mut flat: Vec<u32> = Vec::new();
    let mut bounds = Vec::with_capacity(reqs.len());
    for r in reqs {
        let start = flat.len();
        let local = validate_and_rebase(sh, &r.query, &r.rows,
                                        Some(&r.coord_ids))?;
        flat.extend_from_slice(&local);
        bounds.push((start, flat.len()));
    }
    let views: Vec<PullRequest> = reqs
        .iter()
        .zip(&bounds)
        .map(|(r, &(a, b))| PullRequest {
            query: &r.query,
            rows: &flat[a..b],
            coord_ids: &r.coord_ids,
        })
        .collect();
    engine.pull_batch(&sh.local, &views, metric, sums, sqs);
    Ok(())
}

// ---------------------------------------------------------------------
// health probe (client side of the Stats op)
// ---------------------------------------------------------------------

/// Health snapshot of one shard-server endpoint (the wire `Stats` op):
/// what shard it serves, of which ring size, over which dataset, and how
/// many connections it currently holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EndpointStats {
    /// shard index this server was started as (`shard-serve --shard`)
    pub shard: usize,
    /// ring size it was started for (`shard-serve --of`) — this is what
    /// lets a coordinator size `--remote` from a single live endpoint
    pub of: usize,
    /// global dataset row count
    pub n_total: usize,
    /// dataset dimension
    pub d: usize,
    /// first owned global row
    pub row_start: usize,
    /// one past the last owned global row
    pub row_end: usize,
    /// connections the server currently holds (including this probe's)
    pub live_conns: usize,
}

/// Probe one endpoint with the wire `Stats` health op over a fresh
/// connection. Used by `bmonn ring-stats` to survey a ring's health and
/// layout without issuing any compute.
pub fn endpoint_stats(endpoint: &str, timeout: Option<Duration>)
                      -> Result<EndpointStats, String> {
    let mut stream = connect_endpoint(endpoint, timeout)
        .map_err(|e| format!("{endpoint}: connect failed: {e}"))?;
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    stream.set_read_timeout(timeout).map_err(|e| e.to_string())?;
    stream.set_write_timeout(timeout).map_err(|e| e.to_string())?;
    let mut buf = Vec::new();
    wire::encode_stats(&mut buf);
    wire::write_frame(&mut stream, &buf)
        .map_err(|e| format!("{endpoint}: send failed: {e}"))?;
    wire::read_frame(&mut stream, &mut buf)
        .map_err(|e| format!("{endpoint}: recv failed: {e}"))?;
    match Message::decode(&buf)
        .map_err(|e| format!("{endpoint}: bad reply: {e}"))?
    {
        Message::StatsReply {
            shard, of, n_total, d, row_start, row_end, live_conns,
        } => Ok(EndpointStats {
            shard: shard as usize,
            of: of as usize,
            n_total: n_total as usize,
            d: d as usize,
            row_start: row_start as usize,
            row_end: row_end as usize,
            live_conns: live_conns as usize,
        }),
        Message::Error { msg } => Err(format!("{endpoint}: {msg}")),
        other => Err(format!("{endpoint}: unexpected {} reply",
                             other.kind())),
    }
}

// ---------------------------------------------------------------------
// remote engine (client)
// ---------------------------------------------------------------------

type ShardReply = Result<(Vec<f64>, Vec<f64>), String>;

/// One framed request/reply on an established connection.
fn round_trip(stream: &mut TcpStream, send: &[u8], recv: &mut Vec<u8>,
              ep: &str) -> Result<Message, String> {
    wire::write_frame(stream, send)
        .map_err(|e| format!("{ep}: send failed: {e}"))?;
    wire::read_frame(stream, recv)
        .map_err(|e| format!("{ep}: recv failed: {e}"))?;
    Message::decode(recv).map_err(|e| format!("{ep}: bad reply: {e}"))
}

/// One logical shard's ordered replica endpoints, its single live
/// connection (if any), per-endpoint blacklist state and reusable frame
/// buffers. All failover logic lives here — the wave code above only
/// stages a payload in `sendbuf` and calls `ReplicaSet::request`.
struct ReplicaSet {
    shard: usize,
    n_shards: usize,
    endpoints: Vec<String>,
    states: Vec<EndpointState>,
    /// (endpoint index, stream) of the live connection
    conn: Option<(usize, TcpStream)>,
    sendbuf: Vec<u8>,
    recvbuf: Vec<u8>,
    timeout: Option<Duration>,
    retry: RetryPolicy,
    /// global (n, d) the ring serves — adopted from the first successful
    /// handshake anywhere in the ring, then required of every later one
    /// (including replicas that heal after a restart)
    shape: Option<(usize, usize)>,
}

impl ReplicaSet {
    fn new(shard: usize, n_shards: usize, endpoints: Vec<String>,
           timeout: Option<Duration>, retry: RetryPolicy) -> ReplicaSet {
        let n_eps = endpoints.len();
        ReplicaSet {
            shard,
            n_shards,
            endpoints,
            states: vec![EndpointState::default(); n_eps],
            conn: None,
            sendbuf: Vec::new(),
            recvbuf: Vec::new(),
            timeout,
            retry,
            shape: None,
        }
    }

    /// Dial endpoint `idx`, handshake, and verify it serves this shard's
    /// exact row range of the ring's dataset. On success the connection
    /// is installed and the endpoint's blacklist state heals.
    fn try_endpoint(&mut self, idx: usize) -> Result<(), String> {
        let ep = self.endpoints[idx].clone();
        let mut stream = connect_endpoint(&ep, self.timeout)
            .map_err(|e| format!("{ep}: connect failed: {e}"))?;
        stream.set_nodelay(true).map_err(|e| format!("{ep}: {e}"))?;
        stream
            .set_read_timeout(self.timeout)
            .map_err(|e| format!("{ep}: {e}"))?;
        stream
            .set_write_timeout(self.timeout)
            .map_err(|e| format!("{ep}: {e}"))?;
        // handshake on a scratch buffer: `sendbuf` may hold a wave
        // payload mid-failover and must survive the reconnect
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf);
        wire::write_frame(&mut stream, &buf)
            .map_err(|e| format!("{ep}: handshake send failed: {e}"))?;
        wire::read_frame(&mut stream, &mut buf)
            .map_err(|e| format!("{ep}: handshake recv failed: {e}"))?;
        let (n, d, a, b) = match Message::decode(&buf)
            .map_err(|e| format!("{ep}: bad handshake reply: {e}"))?
        {
            Message::HelloAck { n_total, d, row_start, row_end } => {
                (n_total as usize, d as usize, row_start as usize,
                 row_end as usize)
            }
            other => {
                return Err(format!("{ep}: unexpected {} handshake reply",
                                   other.kind()))
            }
        };
        if let Some((n0, d0)) = self.shape {
            if (n0, d0) != (n, d) {
                return Err(format!(
                    "{ep} serves n={n} d={d} but the ring serves n={n0} \
                     d={d0} — every replica must load one dataset"));
            }
        }
        let (wa, wb) = shard_range(self.shard, n, self.n_shards);
        if (a, b) != (wa, wb) {
            return Err(format!(
                "{ep} serves rows [{a}, {b}) but the {}-way partition of \
                 n={n} assigns [{wa}, {wb}) to shard {} — start it as \
                 shard {} of {}",
                self.n_shards, self.shard, self.shard, self.n_shards));
        }
        self.shape = Some((n, d));
        self.states[idx].record_success();
        self.conn = Some((idx, stream));
        Ok(())
    }

    /// Walk the replica list in order, skipping blacklisted endpoints
    /// and those already attempted during this request, until one
    /// connects. Failures are recorded (extending each endpoint's
    /// backoff) and appended to `errors`.
    fn reconnect(&mut self, attempted: &mut [bool],
                 errors: &mut Vec<String>) -> bool {
        for i in 0..self.endpoints.len() {
            if attempted[i] || !self.states[i].eligible(Instant::now()) {
                continue;
            }
            attempted[i] = true;
            match self.try_endpoint(i) {
                Ok(()) => return true,
                Err(e) => {
                    self.states[i].record_failure(&self.retry,
                                                  Instant::now());
                    errors.push(e);
                }
            }
        }
        false
    }

    /// Try to have a live connection without violating any endpoint's
    /// backoff — the degraded-mode coverage probe. An existing
    /// connection is verified with a `Stats` round-trip (a dead peer's
    /// socket looks open until I/O touches it, and stale coverage would
    /// panic the wave that trusts it); only degraded mode pays this RTT,
    /// once per shard per coverage query. Returns whether the shard is
    /// reachable right now.
    fn probe(&mut self) -> bool {
        if self.conn.is_some() {
            let (idx, stream) = self.conn.as_mut().unwrap();
            let idx = *idx;
            let mut send = Vec::new();
            wire::encode_stats(&mut send);
            let mut recv = Vec::new();
            match round_trip(stream, &send, &mut recv,
                             &self.endpoints[idx]) {
                Ok(Message::StatsReply { .. }) => return true,
                Ok(_) | Err(_) => {
                    self.states[idx].record_failure(&self.retry,
                                                    Instant::now());
                    self.conn = None;
                }
            }
        }
        let mut attempted = vec![false; self.endpoints.len()];
        let mut errors = Vec::new();
        self.reconnect(&mut attempted, &mut errors)
    }

    /// Send the payload staged in `sendbuf` and return the decoded
    /// reply, transparently failing over: an I/O error or corrupt reply
    /// blacklists the current replica (the connection is unusable), a
    /// wire `Error` reply fails over *without* blacklisting (the server
    /// answered — the connection is healthy, only this request failed
    /// server-side, so an unreplicated ring keeps working on the very
    /// next wave). Every endpoint is attempted at most once per
    /// request, so retries are bounded.
    fn request(&mut self) -> Result<Message, String> {
        let mut attempted = vec![false; self.endpoints.len()];
        let mut errors: Vec<String> = Vec::new();
        loop {
            // need a connection on an endpoint not yet tried this wave
            let reusable =
                matches!(&self.conn, Some((idx, _)) if !attempted[*idx]);
            if !reusable && !self.reconnect(&mut attempted, &mut errors) {
                let detail = if errors.is_empty() {
                    "all replicas are backed off after recent failures"
                        .to_string()
                } else {
                    errors.join("; ")
                };
                return Err(format!("shard {}: no live replica: {detail}",
                                   self.shard));
            }
            let (idx, stream) = self.conn.as_mut().unwrap();
            let idx = *idx;
            attempted[idx] = true;
            match round_trip(stream, &self.sendbuf, &mut self.recvbuf,
                             &self.endpoints[idx]) {
                Ok(Message::Error { msg }) => {
                    // server-side failure on a healthy connection: keep
                    // the conn (and the endpoint's clean record), just
                    // fail this request over to the next replica
                    errors.push(format!("{}: {msg}", self.endpoints[idx]));
                }
                Ok(m) => return Ok(m),
                Err(e) => {
                    // I/O failure: the connection is gone — blacklist
                    // the replica and fail over
                    errors.push(e);
                    self.states[idx].record_failure(&self.retry,
                                                    Instant::now());
                    self.conn = None;
                }
            }
        }
    }

    fn expect_sums(&mut self, expected: usize) -> ShardReply {
        match self.request()? {
            Message::Sums { sum, sq } => {
                if sum.len() != expected {
                    return Err(format!(
                        "shard {}: {} results for {expected} requested rows",
                        self.shard,
                        sum.len()
                    ));
                }
                Ok((sum, sq))
            }
            other => Err(format!("shard {}: unexpected {} reply",
                                 self.shard, other.kind())),
        }
    }

    fn expect_dists(&mut self, expected: usize) -> Result<Vec<f64>, String> {
        match self.request()? {
            Message::Dists { vals } => {
                if vals.len() != expected {
                    return Err(format!(
                        "shard {}: {} results for {expected} requested rows",
                        self.shard,
                        vals.len()
                    ));
                }
                Ok(vals)
            }
            other => Err(format!("shard {}: unexpected {} reply",
                                 self.shard, other.kind())),
        }
    }
}

/// Run `per_shard` for every shard that owns part of the current wave.
/// With more than one live sub-wave the round trips overlap on scoped
/// threads; a single live sub-wave skips the spawn and runs inline.
fn fan_out<F>(sets: &mut [ReplicaSet], part: &WavePartition,
              per_shard: F) -> Vec<ShardReply>
where
    F: Fn(&mut ReplicaSet, &ShardWave) -> ShardReply + Sync,
{
    let live = (0..sets.len())
        .filter(|&i| !part.wave(i).rows.is_empty())
        .count();
    if live <= 1 {
        return sets
            .iter_mut()
            .enumerate()
            .map(|(i, c)| {
                let w = part.wave(i);
                if w.rows.is_empty() {
                    Ok((Vec::new(), Vec::new()))
                } else {
                    per_shard(c, w)
                }
            })
            .collect();
    }
    let n = sets.len();
    std::thread::scope(|sc| {
        let per_shard = &per_shard;
        // spawn only for shards that actually own work — an 8-endpoint
        // ring serving a 2-shard wave pays 2 spawns, not 8
        let handles: Vec<_> = sets
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| !part.wave(*i).rows.is_empty())
            .map(|(i, c)| {
                let w = part.wave(i);
                (i, sc.spawn(move || per_shard(c, w)))
            })
            .collect();
        let mut results: Vec<ShardReply> =
            (0..n).map(|_| Ok((Vec::new(), Vec::new()))).collect();
        for (i, h) in handles {
            results[i] = h.join().unwrap_or_else(|_| {
                Err("remote shard I/O thread panicked".into())
            });
        }
        results
    })
}

/// Dial one endpoint, honoring `timeout` during the connect phase too —
/// a blackholed host (no RST) must not strand the caller for the OS SYN
/// retry window.
fn connect_endpoint(ep: &str, timeout: Option<Duration>)
                    -> io::Result<TcpStream> {
    let Some(t) = timeout else {
        return TcpStream::connect(ep);
    };
    let addrs: Vec<SocketAddr> = ep.to_socket_addrs()?.collect();
    let mut last_err = None;
    for addr in addrs {
        match TcpStream::connect_timeout(&addr, t) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidInput,
                       "endpoint resolved to no addresses")
    }))
}

/// Connection options for [`RemoteEngine::connect_opts`].
#[derive(Clone, Copy, Debug)]
pub struct RemoteOptions {
    /// per-connection I/O timeout, applied to connects, reads and writes
    /// (`None` = block forever; tests use short timeouts)
    pub timeout: Option<Duration>,
    /// opt into degraded answers: with every replica of a shard dead,
    /// `RemoteEngine::coverage` reports the surviving rows instead of
    /// waves panicking (`[engine] degraded` / `--degraded`)
    pub degraded: bool,
    /// per-endpoint backoff schedule for the failover blacklist
    pub retry: RetryPolicy,
}

impl Default for RemoteOptions {
    fn default() -> Self {
        RemoteOptions {
            timeout: Some(DEFAULT_IO_TIMEOUT),
            degraded: false,
            retry: RetryPolicy::default(),
        }
    }
}

/// Networked [`PullEngine`] over a replicated ring of shard servers —
/// see the module docs for the ring contract, determinism, failover and
/// degraded-mode semantics.
pub struct RemoteEngine {
    sets: Vec<ReplicaSet>,
    n_total: usize,
    d: usize,
    degraded: bool,
    partition: WavePartition,
}

impl RemoteEngine {
    /// Connect to a ring given one spec per shard (replicas separated by
    /// `|` within a spec), verify every reachable replica serves the
    /// canonical floor-boundary partition, and fail unless each shard
    /// has at least one live replica. Defaults: [`DEFAULT_IO_TIMEOUT`],
    /// degraded off.
    pub fn connect(endpoints: &[String]) -> Result<RemoteEngine, String> {
        Self::connect_opts(&PlacementMap::parse(endpoints)?,
                           RemoteOptions::default())
    }

    /// [`RemoteEngine::connect`] with an explicit per-connection I/O
    /// timeout (`None` = block forever; tests use short timeouts).
    pub fn connect_with_timeout(endpoints: &[String],
                                timeout: Option<Duration>)
                                -> Result<RemoteEngine, String> {
        Self::connect_opts(&PlacementMap::parse(endpoints)?,
                           RemoteOptions { timeout,
                                           ..RemoteOptions::default() })
    }

    /// Connect to every shard's first live replica of `placement` and
    /// verify the ring tiles the dataset with the canonical
    /// floor-boundary partition. Without `opts.degraded`, a shard with
    /// no live replica fails the connect; with it, the shard starts out
    /// down (its rows are excluded from `RemoteEngine::coverage`) and
    /// is re-probed as its endpoints' backoffs expire — at least one
    /// shard must be reachable either way, to learn the dataset shape.
    pub fn connect_opts(placement: &PlacementMap, opts: RemoteOptions)
                        -> Result<RemoteEngine, String> {
        let s = placement.n_shards();
        let mut sets = Vec::with_capacity(s);
        let mut shape: Option<(usize, usize)> = None;
        for i in 0..s {
            let mut set = ReplicaSet::new(i, s,
                                          placement.replicas(i).to_vec(),
                                          opts.timeout, opts.retry);
            set.shape = shape;
            let mut attempted = vec![false; set.endpoints.len()];
            let mut errors = Vec::new();
            if !set.reconnect(&mut attempted, &mut errors)
                && !opts.degraded
            {
                return Err(format!("shard {i}: no live replica: {}",
                                   errors.join("; ")));
            }
            if shape.is_none() {
                shape = set.shape;
            }
            sets.push(set);
        }
        let Some((n_total, d)) = shape else {
            return Err("no shard of the ring is reachable — cannot learn \
                        the dataset shape (degraded mode still needs at \
                        least one live shard)"
                .into());
        };
        // dead-at-connect shards learn the shape the live ones agreed
        // on, so a replica that heals later is validated against it
        for set in &mut sets {
            set.shape = Some((n_total, d));
        }
        Ok(RemoteEngine {
            sets,
            n_total,
            d,
            degraded: opts.degraded,
            partition: WavePartition::new(s),
        })
    }

    /// Number of logical shards in the ring.
    pub fn n_shards(&self) -> usize {
        self.sets.len()
    }

    /// The ring's global dataset shape, learned at handshake.
    pub fn shape(&self) -> (usize, usize) {
        (self.n_total, self.d)
    }

    fn check_dataset(&self, data: &DenseDataset) {
        assert!(
            data.n == self.n_total && data.d == self.d,
            "remote ring serves n={} d={} but this wave's dataset is n={} \
             d={} — every shard server must load the same dataset as the \
             coordinator",
            self.n_total, self.d, data.n, data.d
        );
    }

    fn scatter2(&self, results: Vec<ShardReply>, out_sum: &mut [f64],
                out_sq: &mut [f64]) {
        for (i, res) in results.into_iter().enumerate() {
            match res {
                Ok((sum, sq)) => {
                    let w = self.partition.wave(i);
                    w.scatter(&sum, out_sum);
                    w.scatter(&sq, out_sq);
                }
                Err(e) => panic!("remote pull wave failed: {e}"),
            }
        }
    }
}

impl PullEngine for RemoteEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(rows.len(), 0.0);
        out_sq.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let results = fan_out(&mut self.sets, &self.partition,
                              |shard, wave| {
            wire::encode_partial_sums(&mut shard.sendbuf, metric, query,
                                      &wave.rows, coord_ids);
            shard.expect_sums(wave.rows.len())
        });
        self.scatter2(results, out_sum, out_sq);
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        out.clear();
        out.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let results = fan_out(&mut self.sets, &self.partition,
                              |shard, wave| {
            wire::encode_exact_dists(&mut shard.sendbuf, metric, query,
                                     &wave.rows);
            shard.expect_dists(wave.rows.len()).map(|v| (v, Vec::new()))
        });
        for (i, res) in results.into_iter().enumerate() {
            match res {
                Ok((vals, _)) => self.partition.wave(i).scatter(&vals, out),
                Err(e) => panic!("remote exact wave failed: {e}"),
            }
        }
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        self.check_dataset(data);
        let total = self.partition.split_batch(data.n, reqs);
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        let results = fan_out(&mut self.sets, &self.partition,
                              |shard, wave| {
            let sub: Vec<PullRequest> = wave.subrequests(reqs).collect();
            wire::encode_pull_batch(&mut shard.sendbuf, metric, &sub);
            shard.expect_sums(wave.rows.len())
        });
        self.scatter2(results, out_sum, out_sq);
    }

    /// In degraded mode, the global row ranges whose shards currently
    /// have a live (or immediately reconnectable, backoff permitting)
    /// replica; `None` when every shard is reachable, or when degraded
    /// mode is off (then a dead shard panics the wave instead). Shards
    /// are probed concurrently, so a healthy degraded-mode ring pays
    /// ~one `Stats` round-trip of latency per coverage query, not S.
    fn coverage(&mut self) -> Option<Coverage> {
        if !self.degraded {
            return None;
        }
        let oks: Vec<bool> = if self.sets.len() <= 1 {
            self.sets.iter_mut().map(|s| s.probe()).collect()
        } else {
            std::thread::scope(|sc| {
                let handles: Vec<_> = self
                    .sets
                    .iter_mut()
                    .map(|s| sc.spawn(move || s.probe()))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or(false))
                    .collect()
            })
        };
        let s = self.sets.len();
        let mut live = Vec::new();
        let mut full = true;
        for (i, ok) in oks.into_iter().enumerate() {
            let (a, b) = shard_range(i, self.n_total, s);
            if a == b {
                continue; // a zero-row shard loses nothing when it dies
            }
            if ok {
                live.push((a as u32, b as u32));
            } else {
                full = false;
            }
        }
        if full {
            None
        } else {
            Some(Coverage { live, rows_total: self.n_total })
        }
    }

    fn name(&self) -> &'static str {
        "remote"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn raw_round_trip(stream: &mut TcpStream, payload: &[u8]) -> Message {
        wire::write_frame(stream, payload).unwrap();
        let mut buf = Vec::new();
        wire::read_frame(stream, &mut buf).unwrap();
        Message::decode(&buf).unwrap()
    }

    #[test]
    fn handshake_reports_shape_and_shutdown_stops_the_server() {
        let ds = synthetic::gaussian_iid(10, 8, 1);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 2)
            .unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let mut buf = Vec::new();
        wire::encode_hello(&mut buf);
        match raw_round_trip(&mut stream, &buf) {
            Message::HelloAck { n_total, d, row_start, row_end } => {
                assert_eq!((n_total, d), (10, 8));
                assert_eq!((row_start, row_end), (5, 10));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_shutdown(&mut buf);
        assert_eq!(raw_round_trip(&mut stream, &buf), Message::Ack);
        assert!(srv.shutdown_requested());
    }

    #[test]
    fn stats_op_reports_identity_range_and_connections() {
        let ds = synthetic::gaussian_iid(10, 4, 8);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 1, 3)
            .unwrap(); // owns rows [3, 6)
        let stats = endpoint_stats(&srv.endpoint(),
                                   Some(Duration::from_secs(5)))
            .unwrap();
        assert_eq!(stats.shard, 1);
        assert_eq!(stats.of, 3);
        assert_eq!((stats.n_total, stats.d), (10, 4));
        assert_eq!((stats.row_start, stats.row_end), (3, 6));
        assert!(stats.live_conns >= 1, "probe connection must be counted");
        // a dead endpoint reports an error, not a hang
        let dead = srv.endpoint();
        drop(srv);
        let err = endpoint_stats(&dead, Some(Duration::from_secs(5)))
            .unwrap_err();
        assert!(err.contains(&dead), "got: {err}");
    }

    #[test]
    fn server_answers_errors_for_invalid_requests() {
        let ds = synthetic::gaussian_iid(12, 6, 2);
        let srv = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 3)
            .unwrap(); // owns rows [0, 4)
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        let q = vec![0.0f32; 6];
        let mut buf = Vec::new();
        // out-of-range row
        wire::encode_partial_sums(&mut buf, Metric::L2Sq, &q, &[7], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("row 7")),
            other => panic!("unexpected {}", other.kind()),
        }
        // wrong query dim
        wire::encode_exact_dists(&mut buf, Metric::L1, &[1.0], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("dim")),
            other => panic!("unexpected {}", other.kind()),
        }
        // coordinate out of range
        wire::encode_partial_sums(&mut buf, Metric::L1, &q, &[1], &[99]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Error { msg } => assert!(msg.contains("coordinate")),
            other => panic!("unexpected {}", other.kind()),
        }
        // garbage payload: error reply, connection stays usable
        match raw_round_trip(&mut stream, &[42, 1, 2]) {
            Message::Error { msg } => assert!(msg.contains("bad frame")),
            other => panic!("unexpected {}", other.kind()),
        }
        wire::encode_partial_sums(&mut buf, Metric::L1, &q, &[1], &[0]);
        match raw_round_trip(&mut stream, &buf) {
            Message::Sums { sum, sq } => {
                assert_eq!(sum.len(), 1);
                assert_eq!(sq.len(), 1);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn connect_rejects_a_ring_that_does_not_tile_the_dataset() {
        let ds = synthetic::gaussian_iid(9, 4, 3);
        // both servers claim shard 0 of 2 — the second endpoint's range
        // does not match the partition's assignment for index 1
        let s0 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let s1 = ShardServer::start_shard_of("127.0.0.1:0", &ds, 0, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s1.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("partition"), "got: {err}");
        // mismatched dataset shapes are rejected too
        let other = synthetic::gaussian_iid(7, 4, 4);
        let s2 = ShardServer::start_shard_of("127.0.0.1:0", &other, 1, 2)
            .unwrap();
        let eps = vec![s0.endpoint(), s2.endpoint()];
        let err = RemoteEngine::connect(&eps).unwrap_err();
        assert!(err.contains("one dataset") || err.contains("partition"),
                "got: {err}");
    }

    #[test]
    fn connect_prefers_earlier_replicas_but_tolerates_dead_ones() {
        let ds = synthetic::gaussian_iid(8, 4, 6);
        let (ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        // reserve a port that is then closed: a guaranteed-dead endpoint
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        // shard 0's primary is dead — connect must fall through to the
        // live replica and waves must match the healthy ring bitwise
        let specs = vec![format!("{dead}|{}", eps[0]), eps[1].clone()];
        let mut eng = RemoteEngine::connect_with_timeout(
            &specs, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(eng.shape(), (8, 4));
        let mut healthy = RemoteEngine::connect_with_timeout(
            &eps, Some(Duration::from_secs(5))).unwrap();
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (0..8).collect();
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        eng.partial_sums(&ds, &q, &rows, &[0, 2], Metric::L2Sq, &mut s1,
                         &mut q1);
        healthy.partial_sums(&ds, &q, &rows, &[0, 2], Metric::L2Sq,
                             &mut s2, &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
        // degraded connect with only dead endpoints still fails: the
        // dataset shape cannot be learned from nothing
        let all_dead = vec![dead.clone(), dead];
        let err = RemoteEngine::connect_opts(
            &PlacementMap::parse(&all_dead).unwrap(),
            RemoteOptions { timeout: Some(Duration::from_millis(500)),
                            degraded: true,
                            ..RemoteOptions::default() })
            .unwrap_err();
        assert!(err.contains("reachable"), "got: {err}");
        drop(ring);
    }

    #[test]
    fn wave_against_a_mismatched_dataset_panics_with_context() {
        let ds = synthetic::gaussian_iid(8, 4, 5);
        let (_ring, eps) = spawn_loopback_ring(&ds, 2).unwrap();
        let mut eng = RemoteEngine::connect(&eps).unwrap();
        assert_eq!(eng.shape(), (8, 4));
        assert_eq!(eng.n_shards(), 2);
        assert_eq!(eng.name(), "remote");
        assert_eq!(eng.coverage(), None, "degraded off: never degraded");
        let wrong = synthetic::gaussian_iid(9, 4, 6);
        let q = wrong.row_vec(0);
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let (mut s, mut sq) = (Vec::new(), Vec::new());
                eng.partial_sums(&wrong, &q, &[0], &[0], Metric::L2Sq,
                                 &mut s, &mut sq);
            }))
            .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("same dataset"), "got: {msg}");
    }
}
