//! Stub PJRT runtime, compiled when the `pjrt` feature is off (the
//! default in the offline crate set, which has neither the `xla` PJRT
//! bindings nor `anyhow`).
//!
//! The stub mirrors the public API of the real `runtime::pjrt` module so
//! that the CLI `selftest` subcommand, the integration tests, and
//! `serve_queries --pjrt` all compile unchanged; every constructor returns
//! an error explaining how to enable the real runtime. The [`PullEngine`]
//! impl delegates to the scalar reference so the type remains usable in
//! generic positions (it can never be constructed, so the delegation is
//! unreachable in practice).

use std::path::Path;

use crate::coordinator::arms::{PullEngine, PullRequest, ScalarEngine};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::artifacts::Manifest;

pub type Result<T> = std::result::Result<T, String>;

const UNAVAILABLE: &str =
    "bmonn was built without the `pjrt` feature; rebuild with \
     `--features pjrt` in a workspace that vendors the `xla` and `anyhow` \
     crates to run AOT JAX/Pallas artifacts";

/// Stub counterpart of the compiled-artifact cache.
pub struct PjrtRuntime {
    manifest: Manifest,
}

impl PjrtRuntime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        // Validate the manifest anyway so error messages stay precise.
        let _ = Manifest::load(artifact_dir)?;
        Err(UNAVAILABLE.to_string())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature off)".to_string()
    }
}

/// Stub counterpart of the artifact-backed pull engine.
pub struct PjrtEngine {
    /// telemetry (always 0 — the stub can never be constructed)
    pub executions: u64,
}

impl PjrtEngine {
    pub fn new(_artifact_dir: &Path, _metric: Metric) -> Result<Self> {
        Err(UNAVAILABLE.to_string())
    }

    /// Mirrors the artifact T of the real engine's default bundle.
    pub fn round_pulls(&self) -> u64 {
        256
    }

    pub fn batch_arms(&self) -> usize {
        64
    }
}

impl PullEngine for PjrtEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        ScalarEngine.partial_sums(data, query, rows, coord_ids, metric,
                                  out_sum, out_sq);
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        ScalarEngine.exact_dists(data, query, rows, metric, out);
    }

    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        ScalarEngine.pull_batch(data, reqs, metric, out_sum, out_sq);
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

/// Stub counterpart of the artifact self-check.
pub fn verify_exact_artifact(_rt: &mut PjrtRuntime, _metric: Metric)
                             -> Result<f64> {
    Err(UNAVAILABLE.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_missing_feature() {
        let e = PjrtEngine::new(Path::new("/nonexistent"), Metric::L2Sq)
            .unwrap_err();
        assert!(e.contains("pjrt"), "unexpected error: {e}");
        // runtime: with no manifest present the manifest error wins
        let e = PjrtRuntime::new(Path::new("/nonexistent")).unwrap_err();
        assert!(e.contains("manifest") || e.contains("pjrt"),
                "unexpected error: {e}");
    }
}
