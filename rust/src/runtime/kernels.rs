//! Hot-path row kernels behind runtime CPU-feature dispatch.
//!
//! Every pull wave bottoms out in four per-row kernels: sampled partial
//! moments (Σx, Σx²) over gathered coordinates for ℓ2²/ℓ1, and exact
//! full-row distances for the same two metrics. This module owns those
//! kernels in three tiers:
//!
//! * **scalar** — the portable unrolled loops (previously inlined in
//!   `runtime::native`), the fallback on any CPU and the tier the
//!   cross-substrate bitwise-parity story is anchored on;
//! * **avx2** — `std::arch::x86_64` 8-wide implementations (gathered
//!   loads for the sampled kernels, contiguous loads for the exact
//!   ones), compiled with `#[target_feature(enable = "avx2")]` and only
//!   ever dispatched after `is_x86_feature_detected!("avx2")` succeeds;
//! * **neon** — `std::arch::aarch64` 4-wide implementations (NEON is
//!   baseline on aarch64, so these are safe code).
//!
//! The tier is selected **once, at engine construction** — either
//! auto-detected ([`KernelChoice::Auto`]) or forced (`[engine] kernel` /
//! `--kernel`), never per call — and a [`KernelSet`] of plain function
//! pointers is installed in the engine. Within a fixed tier every kernel
//! is a pure deterministic function of `(row, query-gather, coords)`,
//! accumulating within one row only, so sharded / remote / multiplexed
//! substrates that split waves by *row* stay bitwise-identical to solo
//! execution per tier. Results are **not** bitwise-comparable across
//! tiers (lane widths change the float summation order); the parity
//! tests pin all tiers to `ScalarEngine` within a relative tolerance.
//!
//! **Accumulation error.** All tiers accumulate in f32 lanes for speed
//! but spill to f64 every [`PARTIAL_SPILL_COORDS`] sampled coordinates
//! ([`EXACT_SPILL_DIMS`] dimensions for the exact kernels), bounding the
//! f32 rounding accumulation to a fixed-size block regardless of `t` or
//! `d` — the adversarial large-`t` / large-magnitude property tests pin
//! this against the f64 scalar reference.

#![deny(missing_docs)]

use crate::data::dense::Metric;

/// Sampled-coordinate count per f32 accumulation block of the partial
/// kernels; accumulated block sums spill into f64 at this boundary.
pub const PARTIAL_SPILL_COORDS: usize = 32;

/// Dimensions per f32 accumulation block of the exact kernels.
pub const EXACT_SPILL_DIMS: usize = 64;

/// A concrete kernel implementation tier, resolved from a
/// [`KernelChoice`] at engine construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelTier {
    /// Portable unrolled scalar loops — always available.
    Scalar,
    /// 8-wide AVX2 (`x86_64` with runtime-detected `avx2`).
    Avx2,
    /// 4-wide NEON (`aarch64`; baseline feature there).
    Neon,
}

impl KernelTier {
    /// Stable lowercase name (config value / bench output).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Neon => "neon",
        }
    }
}

/// The configured kernel selection (`[engine] kernel` / `--kernel`):
/// auto-detect the best available tier, or force a specific one —
/// forcing is how deployments keep answers bitwise-identical across
/// heterogeneous machines (every box pinned to the same tier).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Pick the best tier this CPU supports (the default).
    #[default]
    Auto,
    /// Force the portable scalar tier.
    Scalar,
    /// Force AVX2; engine construction fails off-x86_64 or when the CPU
    /// lacks the feature.
    Avx2,
    /// Force NEON; engine construction fails off-aarch64.
    Neon,
}

impl KernelChoice {
    /// Parse a config/CLI value (`auto|scalar|avx2|neon`).
    pub fn parse(s: &str) -> Option<KernelChoice> {
        match s {
            "auto" => Some(KernelChoice::Auto),
            "scalar" => Some(KernelChoice::Scalar),
            "avx2" => Some(KernelChoice::Avx2),
            "neon" => Some(KernelChoice::Neon),
            _ => None,
        }
    }

    /// Stable lowercase name (round-trips through [`KernelChoice::parse`]).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Avx2 => "avx2",
            KernelChoice::Neon => "neon",
        }
    }
}

/// True when `tier`'s kernels may be executed on this machine.
pub fn tier_available(tier: KernelTier) -> bool {
    match tier {
        KernelTier::Scalar => true,
        KernelTier::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        KernelTier::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// The best tier this CPU supports (what [`KernelChoice::Auto`] picks).
pub fn detect() -> KernelTier {
    if tier_available(KernelTier::Avx2) {
        KernelTier::Avx2
    } else if tier_available(KernelTier::Neon) {
        KernelTier::Neon
    } else {
        KernelTier::Scalar
    }
}

/// Resolve a choice to a concrete tier, erroring when a forced tier is
/// not executable on this machine (so a mis-pinned deployment fails at
/// construction instead of silently computing on a different tier).
pub fn resolve(choice: KernelChoice) -> Result<KernelTier, String> {
    let tier = match choice {
        KernelChoice::Auto => return Ok(detect()),
        KernelChoice::Scalar => KernelTier::Scalar,
        KernelChoice::Avx2 => KernelTier::Avx2,
        KernelChoice::Neon => KernelTier::Neon,
    };
    if tier_available(tier) {
        Ok(tier)
    } else {
        Err(format!(
            "--kernel {}: tier not available on this CPU/arch (use \
             --kernel auto, or pin a tier every machine supports)",
            choice.as_str()
        ))
    }
}

/// Sampled partial-moment kernel: `(Σ v, Σ v²)` of
/// `v = metric.coord(row[coords[i]], qg[i])` over all `i`. `qg` is the
/// query pre-gathered at `coords` (same length).
pub type PartialKernel = fn(&[f32], &[f32], &[u32]) -> (f64, f64);

/// Exact full-row distance kernel (un-normalized).
pub type ExactKernel = fn(&[f32], &[f32]) -> f64;

/// The four kernels of one resolved tier, installed in an engine at
/// construction. Plain `fn` pointers: dispatch happens once here, not
/// per row.
#[derive(Clone, Copy, Debug)]
pub struct KernelSet {
    tier: KernelTier,
    partial_l2: PartialKernel,
    partial_l1: PartialKernel,
    exact_l2: ExactKernel,
    exact_l1: ExactKernel,
}

impl KernelSet {
    /// The kernel set of a concrete tier. Panics if the tier is not
    /// executable here — gate with [`resolve`] (which errors instead).
    pub fn for_tier(tier: KernelTier) -> KernelSet {
        assert!(
            tier_available(tier),
            "kernel tier {} not available on this machine",
            tier.as_str()
        );
        match tier {
            KernelTier::Scalar => KernelSet {
                tier,
                partial_l2: scalar::partial_row_l2,
                partial_l1: scalar::partial_row_l1,
                exact_l2: scalar::exact_row_l2,
                exact_l1: scalar::exact_row_l1,
            },
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => KernelSet {
                tier,
                partial_l2: avx2::partial_row_l2,
                partial_l1: avx2::partial_row_l1,
                exact_l2: avx2::exact_row_l2,
                exact_l1: avx2::exact_row_l1,
            },
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => KernelSet {
                tier,
                partial_l2: neon::partial_row_l2,
                partial_l1: neon::partial_row_l1,
                exact_l2: neon::exact_row_l2,
                exact_l1: neon::exact_row_l1,
            },
            #[allow(unreachable_patterns)]
            _ => unreachable!("tier_available gated"),
        }
    }

    /// Kernel set for a choice — [`resolve`] + [`KernelSet::for_tier`].
    pub fn for_choice(choice: KernelChoice) -> Result<KernelSet, String> {
        Ok(KernelSet::for_tier(resolve(choice)?))
    }

    /// The auto-detected kernel set (what `NativeEngine::default` uses).
    pub fn auto() -> KernelSet {
        KernelSet::for_tier(detect())
    }

    /// The tier these kernels belong to.
    pub fn tier(&self) -> KernelTier {
        self.tier
    }

    /// The sampled partial-moment kernel for `metric`.
    pub fn partial(&self, metric: Metric) -> PartialKernel {
        match metric {
            Metric::L2Sq => self.partial_l2,
            Metric::L1 => self.partial_l1,
        }
    }

    /// The exact full-row kernel for `metric`.
    pub fn exact(&self, metric: Metric) -> ExactKernel {
        match metric {
            Metric::L2Sq => self.exact_l2,
            Metric::L1 => self.exact_l1,
        }
    }
}

/// Validate a wave's sampled coordinates against the row length before
/// any kernel runs. The scalar tier would panic on the first
/// out-of-range index anyway; the SIMD tiers use unchecked gathered
/// loads whose soundness rests on this check, so engines call it once
/// per wave (O(t), amortized over the n·t kernel work).
pub fn validate_coords(coords: &[u32], d: usize) {
    for &j in coords {
        assert!(
            (j as usize) < d,
            "sampled coordinate {j} out of range for dimension {d}"
        );
    }
}

/// The portable unrolled tier — fallback on every CPU and the reference
/// the SIMD tiers' parity tests compare against (which in turn is pinned
/// to the f64 `ScalarEngine` loops).
pub(crate) mod scalar {
    use super::{EXACT_SPILL_DIMS, PARTIAL_SPILL_COORDS};

    /// 4-way-unrolled iterations between f64 spills.
    const PARTIAL_SPILL_ITERS: usize = PARTIAL_SPILL_COORDS / 4;
    /// 8-way-unrolled iterations between f64 spills.
    const EXACT_SPILL_ITERS: usize = EXACT_SPILL_DIMS / 8;

    pub(crate) fn partial_row_l2(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        let mut s = 0f64;
        let mut q = 0f64;
        let mut s0 = 0f32;
        let mut s1 = 0f32;
        let mut s2 = 0f32;
        let mut s3 = 0f32;
        let mut q0 = 0f32;
        let mut q1 = 0f32;
        let mut q2 = 0f32;
        let mut q3 = 0f32;
        let chunks = coords.chunks_exact(4);
        let rem = chunks.remainder();
        let mut t = 0usize;
        let mut iters = 0usize;
        for c in chunks {
            // indices validated at wave entry (j < d); qg is sequential
            let d0 = row[c[0] as usize] - qg[t];
            let d1 = row[c[1] as usize] - qg[t + 1];
            let d2 = row[c[2] as usize] - qg[t + 2];
            let d3 = row[c[3] as usize] - qg[t + 3];
            t += 4;
            let v0 = d0 * d0;
            let v1 = d1 * d1;
            let v2 = d2 * d2;
            let v3 = d3 * d3;
            s0 += v0;
            s1 += v1;
            s2 += v2;
            s3 += v3;
            q0 += v0 * v0;
            q1 += v1 * v1;
            q2 += v2 * v2;
            q3 += v3 * v3;
            iters += 1;
            if iters == PARTIAL_SPILL_ITERS {
                s += (s0 + s1) as f64 + (s2 + s3) as f64;
                q += (q0 + q1) as f64 + (q2 + q3) as f64;
                s0 = 0.0;
                s1 = 0.0;
                s2 = 0.0;
                s3 = 0.0;
                q0 = 0.0;
                q1 = 0.0;
                q2 = 0.0;
                q3 = 0.0;
                iters = 0;
            }
        }
        s += (s0 + s1) as f64 + (s2 + s3) as f64;
        q += (q0 + q1) as f64 + (q2 + q3) as f64;
        for &j in rem {
            let dv = (row[j as usize] - qg[t]) as f64;
            t += 1;
            let v = dv * dv;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    pub(crate) fn partial_row_l1(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        // 4-way unrolled accumulators, matching the ℓ2 kernel above
        let mut s = 0f64;
        let mut q = 0f64;
        let mut s0 = 0f32;
        let mut s1 = 0f32;
        let mut s2 = 0f32;
        let mut s3 = 0f32;
        let mut q0 = 0f32;
        let mut q1 = 0f32;
        let mut q2 = 0f32;
        let mut q3 = 0f32;
        let chunks = coords.chunks_exact(4);
        let rem = chunks.remainder();
        let mut t = 0usize;
        let mut iters = 0usize;
        for c in chunks {
            let v0 = (row[c[0] as usize] - qg[t]).abs();
            let v1 = (row[c[1] as usize] - qg[t + 1]).abs();
            let v2 = (row[c[2] as usize] - qg[t + 2]).abs();
            let v3 = (row[c[3] as usize] - qg[t + 3]).abs();
            t += 4;
            s0 += v0;
            s1 += v1;
            s2 += v2;
            s3 += v3;
            q0 += v0 * v0;
            q1 += v1 * v1;
            q2 += v2 * v2;
            q3 += v3 * v3;
            iters += 1;
            if iters == PARTIAL_SPILL_ITERS {
                s += (s0 + s1) as f64 + (s2 + s3) as f64;
                q += (q0 + q1) as f64 + (q2 + q3) as f64;
                s0 = 0.0;
                s1 = 0.0;
                s2 = 0.0;
                s3 = 0.0;
                q0 = 0.0;
                q1 = 0.0;
                q2 = 0.0;
                q3 = 0.0;
                iters = 0;
            }
        }
        s += (s0 + s1) as f64 + (s2 + s3) as f64;
        q += (q0 + q1) as f64 + (q2 + q3) as f64;
        for &j in rem {
            let v = (row[j as usize] - qg[t]).abs() as f64;
            t += 1;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    /// Exact ℓ2² over full rows with 8-way unroll (no gather
    /// indirection), f64 spill per [`EXACT_SPILL_DIMS`]-element block.
    pub(crate) fn exact_row_l2(row: &[f32], query: &[f32]) -> f64 {
        let mut s = 0f64;
        let mut acc = [0f32; 8];
        let n = row.len() / 8 * 8;
        let (head_r, tail_r) = row.split_at(n);
        let (head_q, tail_q) = query.split_at(n);
        let mut iters = 0usize;
        for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8))
        {
            for l in 0..8 {
                let d = rc[l] - qc[l];
                acc[l] += d * d;
            }
            iters += 1;
            if iters == EXACT_SPILL_ITERS {
                for a in &mut acc {
                    s += *a as f64;
                    *a = 0.0;
                }
                iters = 0;
            }
        }
        for a in acc {
            s += a as f64;
        }
        for (r, q) in tail_r.iter().zip(tail_q) {
            let d = (r - q) as f64;
            s += d * d;
        }
        s
    }

    pub(crate) fn exact_row_l1(row: &[f32], query: &[f32]) -> f64 {
        let mut s = 0f64;
        let mut acc = [0f32; 8];
        let n = row.len() / 8 * 8;
        let (head_r, tail_r) = row.split_at(n);
        let (head_q, tail_q) = query.split_at(n);
        let mut iters = 0usize;
        for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8))
        {
            for l in 0..8 {
                acc[l] += (rc[l] - qc[l]).abs();
            }
            iters += 1;
            if iters == EXACT_SPILL_ITERS {
                for a in &mut acc {
                    s += *a as f64;
                    *a = 0.0;
                }
                iters = 0;
            }
        }
        for a in acc {
            s += a as f64;
        }
        for (r, q) in tail_r.iter().zip(tail_q) {
            s += (r - q).abs() as f64;
        }
        s
    }
}

/// The AVX2 tier: 8-wide f32 arithmetic, f64 spill blocks matching the
/// scalar tier's sizes. The sampled kernels gather row values with
/// `vgatherdps` from the wave's coordinate ids; the exact kernels stream
/// contiguous loads. Only dispatched after runtime feature detection.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{EXACT_SPILL_DIMS, PARTIAL_SPILL_COORDS};

    /// 8-wide iterations between f64 spills of the partial kernels.
    const PARTIAL_SPILL_ITERS: usize = PARTIAL_SPILL_COORDS / 8;
    /// 8-wide iterations between f64 spills of the exact kernels.
    const EXACT_SPILL_ITERS: usize = EXACT_SPILL_DIMS / 8;

    /// Widen the 8 f32 lanes to f64 and add them into `acc` (4 f64
    /// lanes; low and high halves summed lane-wise in a fixed order).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn spill(acc: __m256d, v: __m256) -> __m256d {
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
        _mm256_add_pd(acc, _mm256_add_pd(lo, hi))
    }

    /// Sum the 4 f64 lanes in a fixed order: (l0+l2) + (l1+l3).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_pd(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let pair = _mm_add_pd(lo, hi);
        let swapped = _mm_unpackhi_pd(pair, pair);
        _mm_cvtsd_f64(_mm_add_sd(pair, swapped))
    }

    /// One gathered 8-wide step shared by both partial kernels: the
    /// element-wise difference `row[c[i]] - qg[t + i]`.
    ///
    /// # Safety
    /// Requires AVX2 and every index in `c` in-bounds for `row`
    /// (validated per wave by [`super::validate_coords`]), and
    /// `qg[t..t + 8]` in-bounds (guaranteed: qg and coords have equal
    /// length and `t` tracks the chunk offset).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn gather_diff(row: &[f32], qg: &[f32], c: &[u32], t: usize)
                          -> __m256 {
        let idx = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
        let r = _mm256_i32gather_ps::<4>(row.as_ptr(), idx);
        let qv = _mm256_loadu_ps(qg.as_ptr().add(t));
        _mm256_sub_ps(r, qv)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn partial_row_l2_impl(row: &[f32], qg: &[f32], coords: &[u32])
                                  -> (f64, f64) {
        let mut sacc = _mm256_setzero_pd();
        let mut qacc = _mm256_setzero_pd();
        let mut s32 = _mm256_setzero_ps();
        let mut q32 = _mm256_setzero_ps();
        let chunks = coords.chunks_exact(8);
        let rem = chunks.remainder();
        let mut t = 0usize;
        let mut iters = 0usize;
        for c in chunks {
            let dv = gather_diff(row, qg, c, t);
            t += 8;
            let v = _mm256_mul_ps(dv, dv);
            s32 = _mm256_add_ps(s32, v);
            q32 = _mm256_add_ps(q32, _mm256_mul_ps(v, v));
            iters += 1;
            if iters == PARTIAL_SPILL_ITERS {
                sacc = spill(sacc, s32);
                qacc = spill(qacc, q32);
                s32 = _mm256_setzero_ps();
                q32 = _mm256_setzero_ps();
                iters = 0;
            }
        }
        sacc = spill(sacc, s32);
        qacc = spill(qacc, q32);
        let mut s = hsum_pd(sacc);
        let mut q = hsum_pd(qacc);
        for &j in rem {
            let dv = (row[j as usize] - qg[t]) as f64;
            t += 1;
            let v = dv * dv;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn partial_row_l1_impl(row: &[f32], qg: &[f32], coords: &[u32])
                                  -> (f64, f64) {
        let sign = _mm256_set1_ps(-0.0);
        let mut sacc = _mm256_setzero_pd();
        let mut qacc = _mm256_setzero_pd();
        let mut s32 = _mm256_setzero_ps();
        let mut q32 = _mm256_setzero_ps();
        let chunks = coords.chunks_exact(8);
        let rem = chunks.remainder();
        let mut t = 0usize;
        let mut iters = 0usize;
        for c in chunks {
            let dv = gather_diff(row, qg, c, t);
            t += 8;
            let v = _mm256_andnot_ps(sign, dv); // |dv|
            s32 = _mm256_add_ps(s32, v);
            q32 = _mm256_add_ps(q32, _mm256_mul_ps(v, v));
            iters += 1;
            if iters == PARTIAL_SPILL_ITERS {
                sacc = spill(sacc, s32);
                qacc = spill(qacc, q32);
                s32 = _mm256_setzero_ps();
                q32 = _mm256_setzero_ps();
                iters = 0;
            }
        }
        sacc = spill(sacc, s32);
        qacc = spill(qacc, q32);
        let mut s = hsum_pd(sacc);
        let mut q = hsum_pd(qacc);
        for &j in rem {
            let v = (row[j as usize] - qg[t]).abs() as f64;
            t += 1;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exact_row_l2_impl(row: &[f32], query: &[f32]) -> f64 {
        let n = row.len() / 8 * 8;
        let (head_r, tail_r) = row.split_at(n);
        let (head_q, tail_q) = query.split_at(n);
        let mut acc64 = _mm256_setzero_pd();
        let mut acc = _mm256_setzero_ps();
        let mut iters = 0usize;
        for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8))
        {
            let r = _mm256_loadu_ps(rc.as_ptr());
            let q = _mm256_loadu_ps(qc.as_ptr());
            let d = _mm256_sub_ps(r, q);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            iters += 1;
            if iters == EXACT_SPILL_ITERS {
                acc64 = spill(acc64, acc);
                acc = _mm256_setzero_ps();
                iters = 0;
            }
        }
        acc64 = spill(acc64, acc);
        let mut s = hsum_pd(acc64);
        for (r, q) in tail_r.iter().zip(tail_q) {
            let d = (r - q) as f64;
            s += d * d;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exact_row_l1_impl(row: &[f32], query: &[f32]) -> f64 {
        let sign = _mm256_set1_ps(-0.0);
        let n = row.len() / 8 * 8;
        let (head_r, tail_r) = row.split_at(n);
        let (head_q, tail_q) = query.split_at(n);
        let mut acc64 = _mm256_setzero_pd();
        let mut acc = _mm256_setzero_ps();
        let mut iters = 0usize;
        for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8))
        {
            let r = _mm256_loadu_ps(rc.as_ptr());
            let q = _mm256_loadu_ps(qc.as_ptr());
            acc = _mm256_add_ps(
                acc,
                _mm256_andnot_ps(sign, _mm256_sub_ps(r, q)),
            );
            iters += 1;
            if iters == EXACT_SPILL_ITERS {
                acc64 = spill(acc64, acc);
                acc = _mm256_setzero_ps();
                iters = 0;
            }
        }
        acc64 = spill(acc64, acc);
        let mut s = hsum_pd(acc64);
        for (r, q) in tail_r.iter().zip(tail_q) {
            s += (r - q).abs() as f64;
        }
        s
    }

    // Safe fn-pointer shims. SAFETY: `KernelSet::for_tier` only hands
    // these out after `tier_available(Avx2)` (runtime detection)
    // succeeded, and `validate_coords` bounds every gathered index per
    // wave before the partial kernels run.

    pub(super) fn partial_row_l2(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        debug_assert_eq!(qg.len(), coords.len());
        unsafe { partial_row_l2_impl(row, qg, coords) }
    }

    pub(super) fn partial_row_l1(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        debug_assert_eq!(qg.len(), coords.len());
        unsafe { partial_row_l1_impl(row, qg, coords) }
    }

    pub(super) fn exact_row_l2(row: &[f32], query: &[f32]) -> f64 {
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        unsafe { exact_row_l2_impl(row, query) }
    }

    pub(super) fn exact_row_l1(row: &[f32], query: &[f32]) -> f64 {
        debug_assert!(std::arch::is_x86_feature_detected!("avx2"));
        unsafe { exact_row_l1_impl(row, query) }
    }
}

/// The NEON tier: 4-wide f32 arithmetic with the same f64 spill blocks.
/// NEON is a baseline aarch64 feature, so this is safe code (gathers are
/// four scalar indexed loads — aarch64 has no hardware f32 gather).
#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    use super::{EXACT_SPILL_DIMS, PARTIAL_SPILL_COORDS};

    /// 4-wide iterations between f64 spills of the partial kernels.
    const PARTIAL_SPILL_ITERS: usize = PARTIAL_SPILL_COORDS / 4;
    /// 4-wide iterations between f64 spills of the exact kernels.
    const EXACT_SPILL_ITERS: usize = EXACT_SPILL_DIMS / 4;

    /// Widen 4 f32 lanes to f64 and add into `acc` (2 f64 lanes).
    #[inline(always)]
    fn spill(acc: float64x2_t, v: float32x4_t) -> float64x2_t {
        // SAFETY: NEON is baseline on aarch64.
        unsafe {
            let lo = vcvt_f64_f32(vget_low_f32(v));
            let hi = vcvt_high_f64_f32(v);
            vaddq_f64(acc, vaddq_f64(lo, hi))
        }
    }

    #[inline(always)]
    fn hsum(acc: float64x2_t) -> f64 {
        unsafe { vaddvq_f64(acc) }
    }

    #[inline(always)]
    fn gather4(row: &[f32], c: &[u32]) -> float32x4_t {
        let g = [
            row[c[0] as usize],
            row[c[1] as usize],
            row[c[2] as usize],
            row[c[3] as usize],
        ];
        unsafe { vld1q_f32(g.as_ptr()) }
    }

    pub(super) fn partial_row_l2(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        unsafe {
            let mut sacc = vdupq_n_f64(0.0);
            let mut qacc = vdupq_n_f64(0.0);
            let mut s32 = vdupq_n_f32(0.0);
            let mut q32 = vdupq_n_f32(0.0);
            let chunks = coords.chunks_exact(4);
            let rem = chunks.remainder();
            let mut t = 0usize;
            let mut iters = 0usize;
            for c in chunks {
                let r = gather4(row, c);
                let q = vld1q_f32(qg.as_ptr().add(t));
                t += 4;
                let dv = vsubq_f32(r, q);
                let v = vmulq_f32(dv, dv);
                s32 = vaddq_f32(s32, v);
                q32 = vaddq_f32(q32, vmulq_f32(v, v));
                iters += 1;
                if iters == PARTIAL_SPILL_ITERS {
                    sacc = spill(sacc, s32);
                    qacc = spill(qacc, q32);
                    s32 = vdupq_n_f32(0.0);
                    q32 = vdupq_n_f32(0.0);
                    iters = 0;
                }
            }
            sacc = spill(sacc, s32);
            qacc = spill(qacc, q32);
            let mut s = hsum(sacc);
            let mut q = hsum(qacc);
            for &j in rem {
                let dv = (row[j as usize] - qg[t]) as f64;
                t += 1;
                let v = dv * dv;
                s += v;
                q += v * v;
            }
            (s, q)
        }
    }

    pub(super) fn partial_row_l1(row: &[f32], qg: &[f32], coords: &[u32])
                                 -> (f64, f64) {
        unsafe {
            let mut sacc = vdupq_n_f64(0.0);
            let mut qacc = vdupq_n_f64(0.0);
            let mut s32 = vdupq_n_f32(0.0);
            let mut q32 = vdupq_n_f32(0.0);
            let chunks = coords.chunks_exact(4);
            let rem = chunks.remainder();
            let mut t = 0usize;
            let mut iters = 0usize;
            for c in chunks {
                let r = gather4(row, c);
                let q = vld1q_f32(qg.as_ptr().add(t));
                t += 4;
                let v = vabsq_f32(vsubq_f32(r, q));
                s32 = vaddq_f32(s32, v);
                q32 = vaddq_f32(q32, vmulq_f32(v, v));
                iters += 1;
                if iters == PARTIAL_SPILL_ITERS {
                    sacc = spill(sacc, s32);
                    qacc = spill(qacc, q32);
                    s32 = vdupq_n_f32(0.0);
                    q32 = vdupq_n_f32(0.0);
                    iters = 0;
                }
            }
            sacc = spill(sacc, s32);
            qacc = spill(qacc, q32);
            let mut s = hsum(sacc);
            let mut q = hsum(qacc);
            for &j in rem {
                let v = (row[j as usize] - qg[t]).abs() as f64;
                t += 1;
                s += v;
                q += v * v;
            }
            (s, q)
        }
    }

    pub(super) fn exact_row_l2(row: &[f32], query: &[f32]) -> f64 {
        unsafe {
            let n = row.len() / 4 * 4;
            let (head_r, tail_r) = row.split_at(n);
            let (head_q, tail_q) = query.split_at(n);
            let mut acc64 = vdupq_n_f64(0.0);
            let mut acc = vdupq_n_f32(0.0);
            let mut iters = 0usize;
            for (rc, qc) in
                head_r.chunks_exact(4).zip(head_q.chunks_exact(4))
            {
                let d = vsubq_f32(vld1q_f32(rc.as_ptr()),
                                  vld1q_f32(qc.as_ptr()));
                acc = vaddq_f32(acc, vmulq_f32(d, d));
                iters += 1;
                if iters == EXACT_SPILL_ITERS {
                    acc64 = spill(acc64, acc);
                    acc = vdupq_n_f32(0.0);
                    iters = 0;
                }
            }
            acc64 = spill(acc64, acc);
            let mut s = hsum(acc64);
            for (r, q) in tail_r.iter().zip(tail_q) {
                let d = (r - q) as f64;
                s += d * d;
            }
            s
        }
    }

    pub(super) fn exact_row_l1(row: &[f32], query: &[f32]) -> f64 {
        unsafe {
            let n = row.len() / 4 * 4;
            let (head_r, tail_r) = row.split_at(n);
            let (head_q, tail_q) = query.split_at(n);
            let mut acc64 = vdupq_n_f64(0.0);
            let mut acc = vdupq_n_f32(0.0);
            let mut iters = 0usize;
            for (rc, qc) in
                head_r.chunks_exact(4).zip(head_q.chunks_exact(4))
            {
                acc = vaddq_f32(
                    acc,
                    vabsq_f32(vsubq_f32(vld1q_f32(rc.as_ptr()),
                                        vld1q_f32(qc.as_ptr()))),
                );
                iters += 1;
                if iters == EXACT_SPILL_ITERS {
                    acc64 = spill(acc64, acc);
                    acc = vdupq_n_f32(0.0);
                    iters = 0;
                }
            }
            acc64 = spill(acc64, acc);
            let mut s = hsum(acc64);
            for (r, q) in tail_r.iter().zip(tail_q) {
                s += (r - q).abs() as f64;
            }
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    /// f64 reference matching `ScalarEngine`'s summation exactly.
    fn ref_partial(row: &[f32], qg: &[f32], coords: &[u32],
                   metric: Metric) -> (f64, f64) {
        let mut s = 0f64;
        let mut q = 0f64;
        for (i, &j) in coords.iter().enumerate() {
            let v = metric.coord(row[j as usize], qg[i]) as f64;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    fn ref_exact(row: &[f32], query: &[f32], metric: Metric) -> f64 {
        row.iter()
            .zip(query)
            .map(|(&r, &q)| metric.coord(r, q) as f64)
            .sum()
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
    }

    /// Every tier available on this machine (scalar always; plus the
    /// auto-detected SIMD tier when it isn't scalar).
    pub(crate) fn available_tiers() -> Vec<KernelTier> {
        let mut tiers = vec![KernelTier::Scalar];
        if detect() != KernelTier::Scalar {
            tiers.push(detect());
        }
        tiers
    }

    #[test]
    fn choice_parses_and_roundtrips() {
        for c in [KernelChoice::Auto, KernelChoice::Scalar,
                  KernelChoice::Avx2, KernelChoice::Neon]
        {
            assert_eq!(KernelChoice::parse(c.as_str()), Some(c));
        }
        assert_eq!(KernelChoice::parse("sse9"), None);
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
    }

    #[test]
    fn auto_resolves_and_scalar_always_available() {
        assert!(tier_available(KernelTier::Scalar));
        let t = resolve(KernelChoice::Auto).unwrap();
        assert!(tier_available(t));
        assert_eq!(resolve(KernelChoice::Scalar).unwrap(),
                   KernelTier::Scalar);
        // a forced tier for the wrong architecture errors cleanly
        #[cfg(target_arch = "x86_64")]
        assert!(resolve(KernelChoice::Neon).is_err());
        #[cfg(target_arch = "aarch64")]
        assert!(resolve(KernelChoice::Avx2).is_err());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn coord_validation_rejects_out_of_range() {
        validate_coords(&[0, 3, 7], 7);
    }

    /// Satellite harness: adversarial coordinate counts up to (and past)
    /// d = 1024 with large-magnitude rows, pinning every available tier
    /// against the f64 reference. The f32 accumulators only survive this
    /// because of the bounded spill blocks — with unbounded f32
    /// accumulation the ℓ2 second moment drifts past 1e-5 relative error
    /// well before t = 4096 at these magnitudes.
    #[test]
    fn partial_kernels_hold_tolerance_at_large_t_and_magnitude() {
        const TOL: f64 = 1e-5;
        proptest::check(15, |rng: &mut Rng| {
            let d = 1024 + rng.below(1024);
            let scale = [1.0f32, 100.0, 1000.0][rng.below(3)];
            let row: Vec<f32> = (0..d)
                .map(|_| rng.gaussian() as f32 * scale)
                .collect();
            let query: Vec<f32> = (0..d)
                .map(|_| rng.gaussian() as f32 * scale)
                .collect();
            // t from the unroll boundary up to 4 pulls past d
            let t = [7, 63, 1023, d, 2 * d, 4 * d][rng.below(6)];
            let coords: Vec<u32> =
                (0..t).map(|_| rng.below(d) as u32).collect();
            let qg: Vec<f32> =
                coords.iter().map(|&j| query[j as usize]).collect();
            for tier in available_tiers() {
                let ks = KernelSet::for_tier(tier);
                for metric in [Metric::L2Sq, Metric::L1] {
                    let (s, q) = ks.partial(metric)(&row, &qg, &coords);
                    let (rs, rq) = ref_partial(&row, &qg, &coords, metric);
                    crate::prop_assert!(
                        close(s, rs, TOL),
                        "{metric:?} {} t={t} scale={scale} sum: {s} vs \
                         {rs}",
                        tier.as_str()
                    );
                    crate::prop_assert!(
                        close(q, rq, TOL),
                        "{metric:?} {} t={t} scale={scale} sq: {q} vs \
                         {rq}",
                        tier.as_str()
                    );
                }
            }
            Ok(())
        });
    }

    /// Exact kernels under the same adversarial regime: large d, large
    /// magnitudes, dims straddling every tier's vector width.
    #[test]
    fn exact_kernels_hold_tolerance_at_large_d_and_magnitude() {
        const TOL: f64 = 1e-5;
        let mut rng = Rng::new(0x5EED);
        for &d in &[1usize, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1023, 1024,
                    1025, 2048]
        {
            let scale = 1000.0f32;
            let row: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32 * scale).collect();
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32 * scale).collect();
            for tier in available_tiers() {
                let ks = KernelSet::for_tier(tier);
                for metric in [Metric::L2Sq, Metric::L1] {
                    let got = ks.exact(metric)(&row, &query);
                    let want = ref_exact(&row, &query, metric);
                    assert!(
                        close(got, want, TOL),
                        "{metric:?} {} d={d}: {got} vs {want}",
                        tier.as_str()
                    );
                }
            }
        }
    }

    /// SIMD-width boundary sweep: every tier must agree with the scalar
    /// tier at lengths w−1, w, w+1 around each vector/unroll width.
    #[test]
    fn tiers_agree_across_chunk_boundaries() {
        const TOL: f64 = 1e-5;
        let mut rng = Rng::new(0xB0DA);
        let d = 300;
        let row: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32).collect();
        let scalar = KernelSet::for_tier(KernelTier::Scalar);
        for tier in available_tiers() {
            let ks = KernelSet::for_tier(tier);
            for &t in &[1usize, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33,
                        63, 64, 65, 255, 256, 257]
            {
                let coords: Vec<u32> =
                    (0..t).map(|_| rng.below(d) as u32).collect();
                let qg: Vec<f32> =
                    coords.iter().map(|&j| query[j as usize]).collect();
                for metric in [Metric::L2Sq, Metric::L1] {
                    let (s, q) = ks.partial(metric)(&row, &qg, &coords);
                    let (rs, rq) =
                        scalar.partial(metric)(&row, &qg, &coords);
                    assert!(close(s, rs, TOL) && close(q, rq, TOL),
                            "{metric:?} {} t={t}", tier.as_str());
                }
            }
        }
    }
}
