//! Optimized native pull engine — the wall-clock hot path (Fig 6).
//!
//! Semantics identical to `ScalarEngine` (the parity tests enforce this);
//! the difference is mechanical: 4-way unrolled accumulators in f32 (one
//! f64 accumulation per row at the end), branch-free metric dispatch
//! hoisted out of the inner loop, and a coordinate-major gather order that
//! walks each data row once.

use crate::coordinator::arms::{PullEngine, PullRequest};
use crate::data::dense::{DenseDataset, Metric};

#[derive(Default, Clone, Debug)]
pub struct NativeEngine {
    /// query values gathered at the round's sampled coordinates — built
    /// once per partial_sums call so the per-arm inner loop does ONE
    /// random load (row) + one sequential load (qg) per coordinate
    /// instead of two random loads (§Perf iteration 2)
    qg: Vec<f32>,
    /// (data row, request, output slot) jobs of the current pull_batch
    /// wave — engine scratch reused across rounds so the per-round
    /// allocation churn is one-time, not per-wave
    jobs: Vec<(u32, u32, u32)>,
    /// per-request offset into `qg` (pull_batch scratch, same reuse)
    offsets: Vec<usize>,
}

#[inline(always)]
fn partial_row_l2(row: &[f32], qg: &[f32], coords: &[u32]) -> (f64, f64) {
    let mut s0 = 0f32;
    let mut s1 = 0f32;
    let mut s2 = 0f32;
    let mut s3 = 0f32;
    let mut q0 = 0f32;
    let mut q1 = 0f32;
    let mut q2 = 0f32;
    let mut q3 = 0f32;
    let chunks = coords.chunks_exact(4);
    let rem = chunks.remainder();
    let mut t = 0usize;
    for c in chunks {
        // indices validated at sample time (j < d); qg is sequential
        let d0 = row[c[0] as usize] - qg[t];
        let d1 = row[c[1] as usize] - qg[t + 1];
        let d2 = row[c[2] as usize] - qg[t + 2];
        let d3 = row[c[3] as usize] - qg[t + 3];
        t += 4;
        let v0 = d0 * d0;
        let v1 = d1 * d1;
        let v2 = d2 * d2;
        let v3 = d3 * d3;
        s0 += v0;
        s1 += v1;
        s2 += v2;
        s3 += v3;
        q0 += v0 * v0;
        q1 += v1 * v1;
        q2 += v2 * v2;
        q3 += v3 * v3;
    }
    let mut s = (s0 + s1) as f64 + (s2 + s3) as f64;
    let mut q = (q0 + q1) as f64 + (q2 + q3) as f64;
    for &j in rem {
        let d = (row[j as usize] - qg[t]) as f64;
        t += 1;
        let v = d * d;
        s += v;
        q += v * v;
    }
    (s, q)
}

#[inline(always)]
fn partial_row_l1(row: &[f32], qg: &[f32], coords: &[u32]) -> (f64, f64) {
    // 4-way unrolled accumulators, matching the ℓ2 kernel above
    let mut s0 = 0f32;
    let mut s1 = 0f32;
    let mut s2 = 0f32;
    let mut s3 = 0f32;
    let mut q0 = 0f32;
    let mut q1 = 0f32;
    let mut q2 = 0f32;
    let mut q3 = 0f32;
    let chunks = coords.chunks_exact(4);
    let rem = chunks.remainder();
    let mut t = 0usize;
    for c in chunks {
        let v0 = (row[c[0] as usize] - qg[t]).abs();
        let v1 = (row[c[1] as usize] - qg[t + 1]).abs();
        let v2 = (row[c[2] as usize] - qg[t + 2]).abs();
        let v3 = (row[c[3] as usize] - qg[t + 3]).abs();
        t += 4;
        s0 += v0;
        s1 += v1;
        s2 += v2;
        s3 += v3;
        q0 += v0 * v0;
        q1 += v1 * v1;
        q2 += v2 * v2;
        q3 += v3 * v3;
    }
    let mut s = (s0 + s1) as f64 + (s2 + s3) as f64;
    let mut q = (q0 + q1) as f64 + (q2 + q3) as f64;
    for &j in rem {
        let v = (row[j as usize] - qg[t]).abs() as f64;
        t += 1;
        s += v;
        q += v * v;
    }
    (s, q)
}

/// Exact ℓ2² over full rows with 8-way unroll (no gather indirection).
#[inline(always)]
fn exact_row_l2(row: &[f32], query: &[f32]) -> f64 {
    let mut acc = [0f32; 8];
    let n = row.len() / 8 * 8;
    let (head_r, tail_r) = row.split_at(n);
    let (head_q, tail_q) = query.split_at(n);
    for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8)) {
        for l in 0..8 {
            let d = rc[l] - qc[l];
            acc[l] += d * d;
        }
    }
    let mut s = 0f64;
    for a in acc {
        s += a as f64;
    }
    for (r, q) in tail_r.iter().zip(tail_q) {
        let d = (r - q) as f64;
        s += d * d;
    }
    s
}

#[inline(always)]
fn exact_row_l1(row: &[f32], query: &[f32]) -> f64 {
    let mut acc = [0f32; 8];
    let n = row.len() / 8 * 8;
    let (head_r, tail_r) = row.split_at(n);
    let (head_q, tail_q) = query.split_at(n);
    for (rc, qc) in head_r.chunks_exact(8).zip(head_q.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += (rc[l] - qc[l]).abs();
        }
    }
    let mut s = 0f64;
    for a in acc {
        s += a as f64;
    }
    for (r, q) in tail_r.iter().zip(tail_q) {
        s += (r - q).abs() as f64;
    }
    s
}

impl PullEngine for NativeEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        out_sum.clear();
        out_sq.clear();
        out_sum.reserve(rows.len());
        out_sq.reserve(rows.len());
        // gather the query once: per-arm loops then do one random load per
        // coordinate instead of two
        self.qg.clear();
        self.qg.reserve(coord_ids.len());
        for &j in coord_ids {
            self.qg.push(query[j as usize]);
        }
        match metric {
            Metric::L2Sq => {
                for &r in rows {
                    let (s, q) =
                        partial_row_l2(data.row(r as usize), &self.qg,
                                       coord_ids);
                    out_sum.push(s);
                    out_sq.push(q);
                }
            }
            Metric::L1 => {
                for &r in rows {
                    let (s, q) =
                        partial_row_l1(data.row(r as usize), &self.qg,
                                       coord_ids);
                    out_sum.push(s);
                    out_sq.push(q);
                }
            }
        }
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.reserve(rows.len());
        match metric {
            Metric::L2Sq => {
                for &r in rows {
                    out.push(exact_row_l2(data.row(r as usize), query));
                }
            }
            Metric::L1 => {
                for &r in rows {
                    out.push(exact_row_l1(data.row(r as usize), query));
                }
            }
        }
    }

    /// Multi-query coalesced pulls, swept in dataset-row order.
    ///
    /// Every request's query values are gathered once (as in
    /// `partial_sums`), then the (row, request) jobs are sorted by row so
    /// the pass walks the dataset block-by-block: a data row pulled by
    /// many concurrent queries is loaded from memory once per round
    /// instead of once per query. Per-job arithmetic reuses the unrolled
    /// row kernels, so outputs are bit-identical to per-request
    /// `partial_sums` calls.
    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let total: usize = reqs.iter().map(|r| r.rows.len()).sum();
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        // one shared gather buffer, one offset per request (both engine
        // scratch: reused across rounds, no per-wave allocation)
        self.qg.clear();
        self.offsets.clear();
        self.offsets.reserve(reqs.len());
        for r in reqs {
            self.offsets.push(self.qg.len());
            for &j in r.coord_ids {
                self.qg.push(r.query[j as usize]);
            }
        }
        // (data row, request, output slot) jobs in row-major order
        self.jobs.clear();
        self.jobs.reserve(total);
        let mut out_idx = 0u32;
        for (ri, r) in reqs.iter().enumerate() {
            for &row in r.rows {
                self.jobs.push((row, ri as u32, out_idx));
                out_idx += 1;
            }
        }
        self.jobs.sort_unstable_by_key(|&(row, _, _)| row);
        for &(row, ri, oi) in &self.jobs {
            let r = &reqs[ri as usize];
            let off = self.offsets[ri as usize];
            let qg = &self.qg[off..off + r.coord_ids.len()];
            let (s, q) = match metric {
                Metric::L2Sq => {
                    partial_row_l2(data.row(row as usize), qg, r.coord_ids)
                }
                Metric::L1 => {
                    partial_row_l1(data.row(row as usize), qg, r.coord_ids)
                }
            };
            out_sum[oi as usize] = s;
            out_sq[oi as usize] = q;
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::ScalarEngine;
    use crate::data::synthetic;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn parity_with_scalar_engine() {
        proptest::check(40, |rng: &mut Rng| {
            let n = 2 + rng.below(10);
            let d = 1 + rng.below(100);
            let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32).collect();
            let rows: Vec<u32> = (0..n as u32).collect();
            let t = 1 + rng.below(70);
            let coords: Vec<u32> =
                (0..t).map(|_| rng.below(d) as u32).collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let mut scalar = ScalarEngine;
                let mut native = NativeEngine::default();
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                let (mut s2, mut q2) = (Vec::new(), Vec::new());
                scalar.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s1, &mut q1);
                native.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s2, &mut q2);
                for i in 0..n {
                    crate::prop_assert!(
                        (s1[i] - s2[i]).abs() < 1e-3 * s1[i].abs().max(1.0),
                        "sum mismatch {metric:?} row {i}: {} vs {}",
                        s1[i], s2[i]
                    );
                    crate::prop_assert!(
                        (q1[i] - q2[i]).abs() < 1e-2 * q1[i].abs().max(1.0),
                        "sq mismatch {metric:?} row {i}: {} vs {}",
                        q1[i], q2[i]
                    );
                }
                let mut e1 = Vec::new();
                let mut e2 = Vec::new();
                scalar.exact_dists(&ds, &query, &rows, metric, &mut e1);
                native.exact_dists(&ds, &query, &rows, metric, &mut e2);
                for i in 0..n {
                    crate::prop_assert!(
                        (e1[i] - e2[i]).abs() < 1e-3 * e1[i].abs().max(1.0),
                        "exact mismatch {metric:?} row {i}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pull_batch_bitwise_matches_per_request_partial_sums() {
        // The row-major sweep may reorder the work but never the results:
        // each request's outputs must be bit-identical to a standalone
        // partial_sums call.
        proptest::check(20, |rng: &mut Rng| {
            let n = 2 + rng.below(20);
            let d = 4 + rng.below(120);
            let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
            let n_reqs = 1 + rng.below(4);
            let queries: Vec<Vec<f32>> = (0..n_reqs)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let rowsets: Vec<Vec<u32>> = (0..n_reqs)
                .map(|_| {
                    let m = 1 + rng.below(n);
                    (0..m).map(|_| rng.below(n) as u32).collect()
                })
                .collect();
            let coordsets: Vec<Vec<u32>> = (0..n_reqs)
                .map(|_| {
                    let t = 1 + rng.below(70);
                    (0..t).map(|_| rng.below(d) as u32).collect()
                })
                .collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let reqs: Vec<PullRequest> = (0..n_reqs)
                    .map(|i| PullRequest {
                        query: &queries[i],
                        rows: &rowsets[i],
                        coord_ids: &coordsets[i],
                    })
                    .collect();
                let mut native = NativeEngine::default();
                let (mut bs, mut bq) = (Vec::new(), Vec::new());
                native.pull_batch(&ds, &reqs, metric, &mut bs, &mut bq);
                let mut off = 0usize;
                for i in 0..n_reqs {
                    let (mut s, mut q) = (Vec::new(), Vec::new());
                    let mut solo = NativeEngine::default();
                    solo.partial_sums(&ds, &queries[i], &rowsets[i],
                                      &coordsets[i], metric, &mut s,
                                      &mut q);
                    for (j, (&ss, &qq)) in s.iter().zip(&q).enumerate() {
                        crate::prop_assert!(
                            bs[off + j] == ss && bq[off + j] == qq,
                            "req {i} row {j} {metric:?}: batch ({}, {}) \
                             vs solo ({ss}, {qq})",
                            bs[off + j], bq[off + j]
                        );
                    }
                    off += s.len();
                }
                crate::prop_assert!(off == bs.len(),
                                    "output length mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn empty_inputs() {
        let ds = synthetic::gaussian_iid(3, 8, 1);
        let q = ds.row_vec(0);
        let mut e = NativeEngine::default();
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        e.partial_sums(&ds, &q, &[], &[1, 2], Metric::L2Sq, &mut s, &mut sq);
        assert!(s.is_empty());
        e.partial_sums(&ds, &q, &[1], &[], Metric::L2Sq, &mut s, &mut sq);
        assert_eq!(s, vec![0.0]);
    }

    #[test]
    fn submit_complete_tickets_match_blocking_waves_bitwise() {
        // the split API on the native engine resolves eagerly at submit
        // and must be byte-for-byte the blocking call, with tickets
        // completable out of submission order
        let ds = synthetic::gaussian_iid(12, 32, 21);
        let q1 = ds.row_vec(0);
        let q2 = ds.row_vec(1);
        let rows: Vec<u32> = (0..12).collect();
        let coords: Vec<u32> = vec![0, 7, 7, 31, 2];
        let mut e = NativeEngine::default();
        assert!(!e.pipelined());
        let ta = e.submit_partial_sums(&ds, &q1, &rows, &coords,
                                       Metric::L2Sq);
        let tb = e.submit_exact_dists(&ds, &q2, &rows, Metric::L1);
        let req = PullRequest { query: &q1, rows: &rows,
                                coord_ids: &coords };
        let tc = e.submit_pull_batch(&ds, &[req], Metric::L1);
        // complete in reverse order
        let (mut cs, mut cq) = (Vec::new(), Vec::new());
        e.complete_sums(tc, &mut cs, &mut cq);
        let mut bd = Vec::new();
        e.complete_dists(tb, &mut bd);
        let (mut as_, mut aq) = (Vec::new(), Vec::new());
        e.complete_sums(ta, &mut as_, &mut aq);
        let mut solo = NativeEngine::default();
        let (mut ws, mut wq) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &q1, &rows, &coords, Metric::L2Sq, &mut ws,
                          &mut wq);
        assert_eq!(as_, ws);
        assert_eq!(aq, wq);
        let mut wd = Vec::new();
        solo.exact_dists(&ds, &q2, &rows, Metric::L1, &mut wd);
        assert_eq!(bd, wd);
        let (mut wbs, mut wbq) = (Vec::new(), Vec::new());
        solo.pull_batch(&ds, &[req], Metric::L1, &mut wbs, &mut wbq);
        assert_eq!(cs, wbs);
        assert_eq!(cq, wbq);
    }
}
