//! Optimized native pull engine — the wall-clock hot path (Fig 6).
//!
//! Semantics identical to `ScalarEngine` (the parity tests enforce
//! this); the difference is mechanical. The per-row arithmetic lives in
//! [`runtime::kernels`](crate::runtime::kernels): a [`KernelSet`] of
//! scalar / AVX2 / NEON implementations resolved **once at
//! construction** (auto-detected or forced via `[engine] kernel`),
//! never per call. This engine owns the wave mechanics around those
//! kernels: the query is gathered at the round's sampled coordinates
//! once per wave so the per-arm inner loop does ONE random load (row) +
//! one sequential load (qg) per coordinate instead of two, and
//! multi-query `pull_batch` waves are swept in dataset-row order.
//!
//! With the opt-in quantized tier (`[engine] quantized = true`) the
//! sampled waves read an int8 shadow copy of the dataset instead
//! ([`runtime::quant`](crate::runtime::quant)); `exact_dists` always
//! scores on exact f32, and [`PullEngine::quant_bias`] reports the
//! error bound the drivers fold into the confidence half-widths.

use std::sync::Arc;

use crate::coordinator::arms::{PullEngine, PullRequest};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::kernels::{self, KernelChoice, KernelSet, KernelTier};
use crate::runtime::quant::{self, QuantShadow};

#[derive(Clone, Debug)]
pub struct NativeEngine {
    /// the four per-row kernels of the tier resolved at construction
    kernels: KernelSet,
    /// route sampled waves through the int8 shadow dataset
    quantized: bool,
    /// lazily-bound shadow for the dataset this engine is serving,
    /// keyed by buffer address (shards cloning this engine share the
    /// underlying shadow through the process-wide cache)
    shadow: Option<(usize, Arc<QuantShadow>)>,
    /// query values gathered at the round's sampled coordinates — built
    /// once per partial_sums call so the per-arm inner loop does ONE
    /// random load (row) + one sequential load (qg) per coordinate
    /// instead of two (docs/ARCHITECTURE.md, "Hot-path kernels")
    qg: Vec<f32>,
    /// (data row, request, output slot) jobs of the current pull_batch
    /// wave — engine scratch reused across rounds so the per-round
    /// allocation churn is one-time, not per-wave
    jobs: Vec<(u32, u32, u32)>,
    /// per-request offset into `qg` (pull_batch scratch, same reuse)
    offsets: Vec<usize>,
}

impl Default for NativeEngine {
    /// Auto-detected kernel tier, quantized tier off.
    fn default() -> Self {
        NativeEngine::from_kernels(KernelSet::auto(), false)
    }
}

impl NativeEngine {
    /// Engine with an explicit kernel choice (`[engine] kernel` /
    /// `--kernel`) and quantized-tier switch. Errors when a forced
    /// kernel tier is not executable on this machine.
    pub fn with_options(kernel: KernelChoice, quantized: bool)
                        -> Result<NativeEngine, String> {
        Ok(NativeEngine::from_kernels(KernelSet::for_choice(kernel)?,
                                      quantized))
    }

    fn from_kernels(kernels: KernelSet, quantized: bool) -> NativeEngine {
        NativeEngine {
            kernels,
            quantized,
            shadow: None,
            qg: Vec::new(),
            jobs: Vec::new(),
            offsets: Vec::new(),
        }
    }

    /// The kernel tier this engine dispatches to.
    pub fn kernel_tier(&self) -> KernelTier {
        self.kernels.tier()
    }

    /// Whether sampled waves read the int8 quantized shadow.
    pub fn is_quantized(&self) -> bool {
        self.quantized
    }

    /// Bind (build or fetch) the quantized shadow for `data`.
    fn ensure_shadow(&mut self, data: &DenseDataset) {
        let key = data.raw().as_ptr() as usize;
        if !matches!(&self.shadow, Some((k, _)) if *k == key) {
            self.shadow = Some((key, quant::shadow_for(data)));
        }
    }
}

impl PullEngine for NativeEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        // one O(t) bounds pass per wave: the SIMD tiers' gathered loads
        // are unchecked and rely on this
        kernels::validate_coords(coord_ids, data.d);
        out_sum.clear();
        out_sq.clear();
        out_sum.reserve(rows.len());
        out_sq.reserve(rows.len());
        // gather the query once: per-arm loops then do one random load
        // per coordinate instead of two
        self.qg.clear();
        self.qg.reserve(coord_ids.len());
        for &j in coord_ids {
            self.qg.push(query[j as usize]);
        }
        if self.quantized {
            self.ensure_shadow(data);
            let (_, shadow) = self.shadow.as_ref().unwrap();
            for &r in rows {
                let (s, q) = shadow.partial_row(r as usize, &self.qg,
                                                coord_ids, metric);
                out_sum.push(s);
                out_sq.push(q);
            }
            return;
        }
        let kernel = self.kernels.partial(metric);
        for &r in rows {
            let (s, q) = kernel(data.row(r as usize), &self.qg, coord_ids);
            out_sum.push(s);
            out_sq.push(q);
        }
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        // always exact f32 — the quantized tier never touches rescoring
        out.clear();
        out.reserve(rows.len());
        let kernel = self.kernels.exact(metric);
        for &r in rows {
            out.push(kernel(data.row(r as usize), query));
        }
    }

    /// Multi-query coalesced pulls, swept in dataset-row order.
    ///
    /// Every request's query values are gathered once (as in
    /// `partial_sums`), then the (row, request) jobs are sorted by row so
    /// the pass walks the dataset block-by-block: a data row pulled by
    /// many concurrent queries is loaded from memory once per round
    /// instead of once per query. Per-job arithmetic reuses the per-row
    /// kernels, so outputs are bit-identical to per-request
    /// `partial_sums` calls.
    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let total: usize = reqs.iter().map(|r| r.rows.len()).sum();
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        // one shared gather buffer, one offset per request (both engine
        // scratch: reused across rounds, no per-wave allocation)
        self.qg.clear();
        self.offsets.clear();
        self.offsets.reserve(reqs.len());
        for r in reqs {
            kernels::validate_coords(r.coord_ids, data.d);
            self.offsets.push(self.qg.len());
            for &j in r.coord_ids {
                self.qg.push(r.query[j as usize]);
            }
        }
        // (data row, request, output slot) jobs in row-major order
        self.jobs.clear();
        self.jobs.reserve(total);
        let mut out_idx = 0u32;
        for (ri, r) in reqs.iter().enumerate() {
            for &row in r.rows {
                self.jobs.push((row, ri as u32, out_idx));
                out_idx += 1;
            }
        }
        self.jobs.sort_unstable_by_key(|&(row, _, _)| row);
        if self.quantized {
            self.ensure_shadow(data);
            let (_, shadow) = self.shadow.as_ref().unwrap();
            for &(row, ri, oi) in &self.jobs {
                let r = &reqs[ri as usize];
                let off = self.offsets[ri as usize];
                let qg = &self.qg[off..off + r.coord_ids.len()];
                let (s, q) = shadow.partial_row(row as usize, qg,
                                                r.coord_ids, metric);
                out_sum[oi as usize] = s;
                out_sq[oi as usize] = q;
            }
            return;
        }
        let kernel = self.kernels.partial(metric);
        for &(row, ri, oi) in &self.jobs {
            let r = &reqs[ri as usize];
            let off = self.offsets[ri as usize];
            let qg = &self.qg[off..off + r.coord_ids.len()];
            let (s, q) = kernel(data.row(row as usize), qg, r.coord_ids);
            out_sum[oi as usize] = s;
            out_sq[oi as usize] = q;
        }
    }

    fn quant_bias(&mut self, data: &DenseDataset, query: &[f32],
                  metric: Metric) -> f64 {
        if !self.quantized {
            return 0.0;
        }
        self.ensure_shadow(data);
        self.shadow.as_ref().unwrap().1.theta_bias(query, metric)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::ScalarEngine;
    use crate::data::synthetic;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn parity_with_scalar_engine() {
        proptest::check(40, |rng: &mut Rng| {
            let n = 2 + rng.below(10);
            let d = 1 + rng.below(100);
            let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32).collect();
            let rows: Vec<u32> = (0..n as u32).collect();
            let t = 1 + rng.below(70);
            let coords: Vec<u32> =
                (0..t).map(|_| rng.below(d) as u32).collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let mut scalar = ScalarEngine;
                let mut native = NativeEngine::default();
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                let (mut s2, mut q2) = (Vec::new(), Vec::new());
                scalar.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s1, &mut q1);
                native.partial_sums(&ds, &query, &rows, &coords, metric,
                                    &mut s2, &mut q2);
                for i in 0..n {
                    crate::prop_assert!(
                        (s1[i] - s2[i]).abs() < 1e-3 * s1[i].abs().max(1.0),
                        "sum mismatch {metric:?} row {i}: {} vs {}",
                        s1[i], s2[i]
                    );
                    crate::prop_assert!(
                        (q1[i] - q2[i]).abs() < 1e-2 * q1[i].abs().max(1.0),
                        "sq mismatch {metric:?} row {i}: {} vs {}",
                        q1[i], q2[i]
                    );
                }
                let mut e1 = Vec::new();
                let mut e2 = Vec::new();
                scalar.exact_dists(&ds, &query, &rows, metric, &mut e1);
                native.exact_dists(&ds, &query, &rows, metric, &mut e2);
                for i in 0..n {
                    crate::prop_assert!(
                        (e1[i] - e2[i]).abs() < 1e-3 * e1[i].abs().max(1.0),
                        "exact mismatch {metric:?} row {i}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pull_batch_bitwise_matches_per_request_partial_sums() {
        // The row-major sweep may reorder the work but never the results:
        // each request's outputs must be bit-identical to a standalone
        // partial_sums call — on every available kernel tier and on the
        // quantized tier.
        proptest::check(20, |rng: &mut Rng| {
            let n = 2 + rng.below(20);
            let d = 4 + rng.below(120);
            let ds = synthetic::gaussian_iid(n, d, rng.next_u64());
            let n_reqs = 1 + rng.below(4);
            let queries: Vec<Vec<f32>> = (0..n_reqs)
                .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
                .collect();
            let rowsets: Vec<Vec<u32>> = (0..n_reqs)
                .map(|_| {
                    let m = 1 + rng.below(n);
                    (0..m).map(|_| rng.below(n) as u32).collect()
                })
                .collect();
            let coordsets: Vec<Vec<u32>> = (0..n_reqs)
                .map(|_| {
                    let t = 1 + rng.below(70);
                    (0..t).map(|_| rng.below(d) as u32).collect()
                })
                .collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let reqs: Vec<PullRequest> = (0..n_reqs)
                    .map(|i| PullRequest {
                        query: &queries[i],
                        rows: &rowsets[i],
                        coord_ids: &coordsets[i],
                    })
                    .collect();
                for quantized in [false, true] {
                    let mk = || {
                        NativeEngine::with_options(KernelChoice::Auto,
                                                   quantized)
                            .unwrap()
                    };
                    let mut native = mk();
                    let (mut bs, mut bq) = (Vec::new(), Vec::new());
                    native.pull_batch(&ds, &reqs, metric, &mut bs,
                                      &mut bq);
                    let mut off = 0usize;
                    for i in 0..n_reqs {
                        let (mut s, mut q) = (Vec::new(), Vec::new());
                        let mut solo = mk();
                        solo.partial_sums(&ds, &queries[i], &rowsets[i],
                                          &coordsets[i], metric, &mut s,
                                          &mut q);
                        for (j, (&ss, &qq)) in
                            s.iter().zip(&q).enumerate()
                        {
                            crate::prop_assert!(
                                bs[off + j] == ss && bq[off + j] == qq,
                                "req {i} row {j} {metric:?} quant={} : \
                                 batch ({}, {}) vs solo ({ss}, {qq})",
                                quantized, bs[off + j], bq[off + j]
                            );
                        }
                        off += s.len();
                    }
                    crate::prop_assert!(off == bs.len(),
                                        "output length mismatch");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_inputs() {
        let ds = synthetic::gaussian_iid(3, 8, 1);
        let q = ds.row_vec(0);
        let mut e = NativeEngine::default();
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        e.partial_sums(&ds, &q, &[], &[1, 2], Metric::L2Sq, &mut s, &mut sq);
        assert!(s.is_empty());
        e.partial_sums(&ds, &q, &[1], &[], Metric::L2Sq, &mut s, &mut sq);
        assert_eq!(s, vec![0.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_coordinate_rejected_before_kernels() {
        let ds = synthetic::gaussian_iid(3, 8, 2);
        let q = ds.row_vec(0);
        let mut e = NativeEngine::default();
        let (mut s, mut sq) = (Vec::new(), Vec::new());
        e.partial_sums(&ds, &q, &[0, 1], &[3, 8], Metric::L2Sq, &mut s,
                       &mut sq);
    }

    #[test]
    fn forced_scalar_tier_and_quantized_construction() {
        let e = NativeEngine::with_options(KernelChoice::Scalar, false)
            .unwrap();
        assert_eq!(e.kernel_tier(), KernelTier::Scalar);
        assert!(!e.is_quantized());
        let q = NativeEngine::with_options(KernelChoice::Auto, true)
            .unwrap();
        assert!(q.is_quantized());
        // forcing a tier the architecture can't run errors cleanly
        #[cfg(target_arch = "x86_64")]
        assert!(NativeEngine::with_options(KernelChoice::Neon, false)
            .is_err());
        #[cfg(target_arch = "aarch64")]
        assert!(NativeEngine::with_options(KernelChoice::Avx2, false)
            .is_err());
    }

    #[test]
    fn quantized_estimates_stay_within_reported_bias() {
        // the engine-level version of the quant unit test: partial_sums
        // per-pull estimates off the shadow stay within quant_bias of
        // the exact-f32 engine's, and exact_dists is untouched
        let mut rng = Rng::new(0x0555);
        let n = 40;
        let d = 96;
        let mut ds = DenseDataset::zeros(n, d);
        for r in 0..n {
            for v in ds.row_mut(r) {
                *v = rng.gaussian() as f32 * 50.0;
            }
        }
        let query: Vec<f32> =
            (0..d).map(|_| rng.gaussian() as f32 * 50.0).collect();
        let rows: Vec<u32> = (0..n as u32).collect();
        let t = 64;
        let coords: Vec<u32> =
            (0..t).map(|_| rng.below(d) as u32).collect();
        for metric in [Metric::L2Sq, Metric::L1] {
            let mut exact = NativeEngine::default();
            let mut quant =
                NativeEngine::with_options(KernelChoice::Auto, true)
                    .unwrap();
            let bias = quant.quant_bias(&ds, &query, metric);
            assert!(bias > 0.0, "quantized engine must report a bias");
            assert_eq!(exact.quant_bias(&ds, &query, metric), 0.0);
            let (mut s1, mut q1) = (Vec::new(), Vec::new());
            let (mut s2, mut q2) = (Vec::new(), Vec::new());
            exact.partial_sums(&ds, &query, &rows, &coords, metric,
                               &mut s1, &mut q1);
            quant.partial_sums(&ds, &query, &rows, &coords, metric,
                               &mut s2, &mut q2);
            let td = t as f64;
            for i in 0..n {
                assert!(
                    (s1[i] / td - s2[i] / td).abs() <= bias + 1e-9,
                    "{metric:?} row {i}: quantized estimate strayed \
                     past the reported bias"
                );
            }
            let mut e1 = Vec::new();
            let mut e2 = Vec::new();
            exact.exact_dists(&ds, &query, &rows, metric, &mut e1);
            quant.exact_dists(&ds, &query, &rows, metric, &mut e2);
            assert_eq!(e1, e2, "exact_dists must bypass quantization");
        }
    }

    #[test]
    fn submit_complete_tickets_match_blocking_waves_bitwise() {
        // the split API on the native engine resolves eagerly at submit
        // and must be byte-for-byte the blocking call, with tickets
        // completable out of submission order
        let ds = synthetic::gaussian_iid(12, 32, 21);
        let q1 = ds.row_vec(0);
        let q2 = ds.row_vec(1);
        let rows: Vec<u32> = (0..12).collect();
        let coords: Vec<u32> = vec![0, 7, 7, 31, 2];
        let mut e = NativeEngine::default();
        assert!(!e.pipelined());
        let ta = e.submit_partial_sums(&ds, &q1, &rows, &coords,
                                       Metric::L2Sq);
        let tb = e.submit_exact_dists(&ds, &q2, &rows, Metric::L1);
        let req = PullRequest { query: &q1, rows: &rows,
                                coord_ids: &coords };
        let tc = e.submit_pull_batch(&ds, &[req], Metric::L1);
        // complete in reverse order
        let (mut cs, mut cq) = (Vec::new(), Vec::new());
        e.complete_sums(tc, &mut cs, &mut cq);
        let mut bd = Vec::new();
        e.complete_dists(tb, &mut bd);
        let (mut as_, mut aq) = (Vec::new(), Vec::new());
        e.complete_sums(ta, &mut as_, &mut aq);
        let mut solo = NativeEngine::default();
        let (mut ws, mut wq) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &q1, &rows, &coords, Metric::L2Sq, &mut ws,
                          &mut wq);
        assert_eq!(as_, ws);
        assert_eq!(aq, wq);
        let mut wd = Vec::new();
        solo.exact_dists(&ds, &q2, &rows, Metric::L1, &mut wd);
        assert_eq!(bd, wd);
        let (mut wbs, mut wbq) = (Vec::new(), Vec::new());
        solo.pull_batch(&ds, &[req], Metric::L1, &mut wbs, &mut wbq);
        assert_eq!(cs, wbs);
        assert_eq!(cq, wbq);
    }
}
