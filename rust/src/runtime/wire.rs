//! Length-prefixed binary wire protocol, **version 3**: every frame is
//! tagged with a `wave_id`, which is what lets one connection carry many
//! concurrent waves (`runtime::remote::RingClient` multiplexes sub-waves
//! from many callers onto one connection per shard and demultiplexes the
//! replies by tag — replies may arrive in any order), and every
//! handshake/health reply is stamped with the serving **placement
//! epoch** so a coordinator can prove which placement generation an
//! endpoint belongs to while the ring is resharded live.
//!
//! Framing: every message travels as `u32 payload_len (LE) | payload`,
//! where `payload[0]` is an opcode byte, `payload[1..9]` is the frame's
//! little-endian `u64` **wave id**, and the rest is a fixed-layout
//! little-endian body. A reply carries the wave id of the request it
//! answers. [`read_frame`] rejects frames whose declared length exceeds
//! [`MAX_FRAME`] *before* allocating, and [`Message::decode`] rejects
//! truncated payloads, trailing garbage, unknown opcodes and bad metric
//! codes with an `Err` — never a panic (property-tested below: every
//! strict prefix of a valid payload fails to decode).
//!
//! **Version negotiation.** v1 (PR 3/4) frames were untagged and used
//! opcodes 1–12; v2 (PR 5) frames use opcodes 101–112 and begin with
//! the wave tag. A v3 decoder recognizes a v1 opcode and rejects it
//! with a clean *version* error ([`Message::decode`],
//! [`is_legacy_frame`]); a v3 server answers a v1 frame with a
//! **v1-framed** `Error` ([`encode_legacy_error`]) so an old client
//! reads a clean protocol error instead of hanging or crashing on bytes
//! it cannot parse. A client talking to a v1 server receives a v1
//! `Error { "unknown opcode …" }` reply, which its decoder likewise
//! reports as a version mismatch.
//!
//! v3 negotiates with the explicit version field the v2 handshake
//! introduced for exactly this purpose: `Hello` keeps opcode 101 and
//! its layout, and every message whose layout is unchanged keeps its
//! v2 opcode. The two messages that *grew* — `HelloAck` and
//! `StatsReply` now carry the placement epoch — moved to fresh opcodes
//! (113/114; layouts never change under an existing opcode), their
//! retired v2 opcodes (102/112) are rejected with an explicit
//! version-mismatch error, and the transfer ops (115–117) are new. The
//! negotiation is therefore symmetric and clean in both directions: a
//! v2 **client** sends `Hello { version: 2 }`, which a v3 server
//! rejects with a tagged `Error` naming both versions — in framing a
//! v2 peer parses, since the `Error` layout is identical across
//! v2/v3; a v3 **client** announcing `version: 3` to a v2 server gets
//! the same mismatch `Error` back from the v2 version gate and refuses
//! the endpoint with an upgrade message.
//!
//! Requests (coordinator → shard server):
//! * `Hello` — handshake; carries the client's protocol version. The
//!   server answers [`Message::HelloAck`] with its version, the global
//!   dataset shape, the row range it owns and its **dataset
//!   fingerprint** ([`dataset_fingerprint`]), which lets the client
//!   prove the ring tiles the dataset with the same floor-boundary
//!   partition the in-process sharded engine uses and that every
//!   replica of a shard serves identical bytes.
//! * `Stats` — the health op: may be sent at any point on a connection.
//!   The server answers [`Message::StatsReply`] with its shard identity
//!   (`shard` of `of`), dataset shape, owned row range,
//!   live-connection count, dataset fingerprint and the high-water mark
//!   of concurrent waves it has served on one connection — see the
//!   `bmonn ring-stats` subcommand.
//! * `PartialSums` / `ExactDists` / `PullBatch` — one engine sub-wave,
//!   rows given as **global** ids; the server rebases them onto its
//!   local row range and rejects anything outside it. A server may
//!   compute several tagged waves of one connection concurrently and
//!   answer them out of submission order.
//! * `TransferBegin` / `TransferRows` / `TransferCommit` — the reshard
//!   stream (v3): a coordinator announces a shard assignment to a
//!   **staging** server (one started without a dataset), streams the
//!   row range to it in chunks, and commits with the expected
//!   [`dataset_fingerprint`] — the server recomputes the fingerprint
//!   over the bytes it actually received and installs the dataset only
//!   on a match, answering `Ack` (or `Error` on mismatch, so a corrupt
//!   transfer can never start serving). Servers already serving a
//!   dataset answer transfer requests with `Error`.
//! * `Shutdown` — acked with [`Message::Ack`], then the server exits.
//!
//! Replies (shard server → coordinator): `HelloAck`, `StatsReply`
//! (both stamped with the serving placement epoch),
//! `Sums { sum, sq }` (for `PartialSums` and `PullBatch`, concatenated
//! request-major), `Dists { vals }`, `Error { msg }`, `Ack` — each
//! tagged with the request's wave id.
//!
//! An `Error` reply is also a failover trigger: the replicated client
//! re-issues the sub-wave to the shard's next live replica (without
//! blacklisting the answering server — its connection is healthy, only
//! the request failed).
//!
//! All floats cross the wire via `to_le_bytes`/`from_le_bytes`, i.e. by
//! exact bit pattern — the transport can never perturb the bitwise
//! parity the engines are pinned to.
//!
//! The byte-level layout of every message is specified normatively in
//! `docs/WIRE_PROTOCOL.md`.

#![deny(missing_docs)]

use std::io::{self, Read, Write};

use crate::coordinator::arms::PullRequest;
use crate::data::dense::{DenseDataset, Metric};

/// Wire protocol revision this build speaks. v1 frames (untagged,
/// opcodes 1–12) and the retired v2 reply opcodes (102/112 — their
/// layouts grew an epoch field and moved to 113/114) are recognized and
/// rejected with a clean version error.
pub const PROTOCOL_VERSION: u32 = 3;

/// Hard cap on a single frame's payload (1 GiB). A real wave is far
/// smaller (a 4M-job reply is ~64 MiB); a length header beyond this is a
/// corrupt or hostile stream and is rejected before any allocation.
pub const MAX_FRAME: usize = 1 << 30;

// v1 opcode range — recognized only to produce clean version errors.
const V1_OP_MIN: u8 = 1;
const V1_OP_MAX: u8 = 12;
const V1_OP_ERROR: u8 = 8;

// Retired v2 reply opcodes. Their messages gained an epoch field in
// v3, and a changed layout always moves to a fresh opcode — these are
// recognized only to produce clean version errors, never reused.
const V2_OP_HELLO_ACK: u8 = 102;
const V2_OP_STATS_REPLY: u8 = 112;

const OP_HELLO: u8 = 101;
const OP_PARTIAL_SUMS: u8 = 103;
const OP_EXACT_DISTS: u8 = 104;
const OP_PULL_BATCH: u8 = 105;
const OP_SUMS: u8 = 106;
const OP_DISTS: u8 = 107;
const OP_ERROR: u8 = 108;
const OP_SHUTDOWN: u8 = 109;
const OP_ACK: u8 = 110;
const OP_STATS: u8 = 111;
const OP_HELLO_ACK: u8 = 113;
const OP_STATS_REPLY: u8 = 114;
const OP_TRANSFER_BEGIN: u8 = 115;
const OP_TRANSFER_ROWS: u8 = 116;
const OP_TRANSFER_COMMIT: u8 = 117;

fn metric_code(m: Metric) -> u8 {
    match m {
        Metric::L2Sq => 0,
        Metric::L1 => 1,
    }
}

fn metric_from(code: u8) -> Result<Metric, String> {
    match code {
        0 => Ok(Metric::L2Sq),
        1 => Ok(Metric::L1),
        x => Err(format!("bad metric code {x}")),
    }
}

/// True when `payload` begins with a v1 (untagged) opcode — an
/// old-version peer. A v2 server answers such a frame with
/// [`encode_legacy_error`] so the old client reads a clean protocol
/// error in a format it can parse.
pub fn is_legacy_frame(payload: &[u8]) -> bool {
    payload
        .first()
        .is_some_and(|&op| (V1_OP_MIN..=V1_OP_MAX).contains(&op))
}

/// Best-effort wave id of a frame whose body failed to decode: the tag
/// occupies fixed bytes `[1, 9)`, so it usually survives body
/// corruption. Returns 0 when the frame is too short to carry one.
pub fn peek_wave_id(payload: &[u8]) -> u64 {
    if payload.len() >= 9 {
        u64::from_le_bytes(payload[1..9].try_into().unwrap())
    } else {
        0
    }
}

/// Marker every deadline-budget failure carries, so callers up the
/// stack (the query server's panic handler, CLI drivers) can tell "the
/// query ran out of its time budget" apart from "the ring is broken"
/// without a typed error channel: wave errors travel as strings (and
/// as panic payloads through `complete_sums`/`complete_dists`, whose
/// trait signatures have no `Result`). Producers prefix their message
/// with it; consumers classify with [`is_deadline_error`].
pub const DEADLINE_ERROR: &str = "deadline exceeded";

/// True when `msg` is (or wraps) a deadline-budget failure produced by
/// a wave wait or a batch driver's between-round budget check. Matches
/// anywhere in the string because engine layers wrap wave errors with
/// context ("remote pull wave failed: deadline exceeded: ...").
pub fn is_deadline_error(msg: &str) -> bool {
    msg.contains(DEADLINE_ERROR)
}

/// FNV-1a 64 fingerprint of the dataset content a shard server holds:
/// global shape, owned row range, and the exact f32 bit pattern of every
/// local row. Replicas of one shard must agree on it (they serve the
/// same rows of the same dataset); different shards of one ring
/// legitimately differ (they hold different rows). Carried in
/// `HelloAck`/`StatsReply`; the ring client refuses a replica whose
/// fingerprint diverges from its shard-mates', and `bmonn ring-stats`
/// reports divergence with a nonzero exit.
pub fn dataset_fingerprint(n_total: usize, row_start: usize,
                           local: &DenseDataset) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut eat = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    eat(n_total as u64);
    eat(local.d as u64);
    eat(row_start as u64);
    eat(local.n as u64);
    for &v in local.raw() {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

// ---------------------------------------------------------------------
// encoding — each `encode_*` clears `out` and writes one full payload;
// the client-side helpers take borrowed slices so the hot path never
// copies a wave into an owned message first
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_head(out: &mut Vec<u8>, op: u8, wave_id: u64) {
    out.clear();
    out.push(op);
    put_u64(out, wave_id);
}

/// Encode a `Hello` handshake request carrying the client's protocol
/// version.
pub fn encode_hello(out: &mut Vec<u8>, wave_id: u64, version: u32) {
    put_head(out, OP_HELLO, wave_id);
    put_u32(out, version);
}

/// Encode the `HelloAck` handshake reply: server protocol version,
/// global dataset shape, the owned row range `[row_start, row_end)`,
/// the server's dataset fingerprint and the placement epoch it serves.
#[allow(clippy::too_many_arguments)]
pub fn encode_hello_ack(out: &mut Vec<u8>, wave_id: u64, version: u32,
                        n_total: u64, d: u64, row_start: u64, row_end: u64,
                        data_hash: u64, epoch: u64) {
    put_head(out, OP_HELLO_ACK, wave_id);
    put_u32(out, version);
    put_u64(out, n_total);
    put_u64(out, d);
    put_u64(out, row_start);
    put_u64(out, row_end);
    put_u64(out, data_hash);
    put_u64(out, epoch);
}

/// Encode a `Stats` health request (no body beyond the tag).
pub fn encode_stats(out: &mut Vec<u8>, wave_id: u64) {
    put_head(out, OP_STATS, wave_id);
}

/// Encode a `StatsReply`: shard identity (`shard` of `of`), dataset
/// shape, owned row range, the server's live-connection count, its
/// dataset fingerprint, the high-water mark of concurrent waves it
/// has computed on a single connection, and the placement epoch it
/// serves.
#[allow(clippy::too_many_arguments)]
pub fn encode_stats_reply(out: &mut Vec<u8>, wave_id: u64, shard: u64,
                          of: u64, n_total: u64, d: u64, row_start: u64,
                          row_end: u64, live_conns: u64, data_hash: u64,
                          max_conn_waves: u64, epoch: u64) {
    put_head(out, OP_STATS_REPLY, wave_id);
    put_u64(out, shard);
    put_u64(out, of);
    put_u64(out, n_total);
    put_u64(out, d);
    put_u64(out, row_start);
    put_u64(out, row_end);
    put_u64(out, live_conns);
    put_u64(out, data_hash);
    put_u64(out, max_conn_waves);
    put_u64(out, epoch);
}

/// Encode a `TransferBegin` request: the shard assignment the streamed
/// rows are for — identity `shard` of `of`, global dataset shape, the
/// row range about to be streamed (which must be exactly the
/// floor-boundary range of that shard), and the placement epoch the
/// target will serve once committed. A fresh `TransferBegin` replaces
/// any half-streamed transfer on the target, so a flapped stream is
/// restarted from scratch, never resumed into a corrupt buffer.
#[allow(clippy::too_many_arguments)]
pub fn encode_transfer_begin(out: &mut Vec<u8>, wave_id: u64, shard: u64,
                             of: u64, n_total: u64, d: u64, row_start: u64,
                             row_end: u64, epoch: u64) {
    put_head(out, OP_TRANSFER_BEGIN, wave_id);
    put_u64(out, shard);
    put_u64(out, of);
    put_u64(out, n_total);
    put_u64(out, d);
    put_u64(out, row_start);
    put_u64(out, row_end);
    put_u64(out, epoch);
}

/// Encode a `TransferRows` chunk: `row_offset` rows into the announced
/// range, then the chunk's f32 values (whole rows; `data.len()` must be
/// a multiple of the announced `d`). Floats cross by bit pattern like
/// every other frame — the installed dataset fingerprints identically
/// to the source.
pub fn encode_transfer_rows(out: &mut Vec<u8>, wave_id: u64,
                            row_offset: u64, data: &[f32]) {
    put_head(out, OP_TRANSFER_ROWS, wave_id);
    put_u64(out, row_offset);
    put_f32s(out, data);
}

/// Encode a `TransferCommit` request carrying the sender's
/// [`dataset_fingerprint`] of the streamed range. The target recomputes
/// the fingerprint over what it received and installs the dataset only
/// on a match (`Ack`); a mismatch answers `Error` and discards the
/// staged rows.
pub fn encode_transfer_commit(out: &mut Vec<u8>, wave_id: u64,
                              data_hash: u64) {
    put_head(out, OP_TRANSFER_COMMIT, wave_id);
    put_u64(out, data_hash);
}

/// Encode a `PartialSums` wave request from borrowed slices (rows are
/// global ids).
pub fn encode_partial_sums(out: &mut Vec<u8>, wave_id: u64, metric: Metric,
                           query: &[f32], rows: &[u32],
                           coord_ids: &[u32]) {
    put_head(out, OP_PARTIAL_SUMS, wave_id);
    out.push(metric_code(metric));
    put_f32s(out, query);
    put_u32s(out, rows);
    put_u32s(out, coord_ids);
}

/// Encode an `ExactDists` wave request from borrowed slices.
pub fn encode_exact_dists(out: &mut Vec<u8>, wave_id: u64, metric: Metric,
                          query: &[f32], rows: &[u32]) {
    put_head(out, OP_EXACT_DISTS, wave_id);
    out.push(metric_code(metric));
    put_f32s(out, query);
    put_u32s(out, rows);
}

/// Encode a `PullBatch` wave request straight from the coordinator's
/// borrowed [`PullRequest`] views (the hot path never copies a wave into
/// an owned message first).
pub fn encode_pull_batch(out: &mut Vec<u8>, wave_id: u64, metric: Metric,
                         reqs: &[PullRequest<'_>]) {
    put_head(out, OP_PULL_BATCH, wave_id);
    out.push(metric_code(metric));
    put_u32(out, reqs.len() as u32);
    for r in reqs {
        put_f32s(out, r.query);
        put_u32s(out, r.rows);
        put_u32s(out, r.coord_ids);
    }
}

/// `sum` and `sq` must have equal length (one shared count on the wire).
pub fn encode_sums(out: &mut Vec<u8>, wave_id: u64, sum: &[f64],
                   sq: &[f64]) {
    assert_eq!(sum.len(), sq.len());
    put_head(out, OP_SUMS, wave_id);
    put_u32(out, sum.len() as u32);
    for &x in sum {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in sq {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a `Dists` reply (exact distances, one per requested row).
pub fn encode_dists(out: &mut Vec<u8>, wave_id: u64, vals: &[f64]) {
    put_head(out, OP_DISTS, wave_id);
    put_f64s(out, vals);
}

/// Encode an `Error` reply carrying a human-readable message.
pub fn encode_error(out: &mut Vec<u8>, wave_id: u64, msg: &str) {
    put_head(out, OP_ERROR, wave_id);
    let bytes = msg.as_bytes();
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

/// Encode an `Error` in the **v1** layout (`op 8 | u32 len | bytes`) —
/// the one frame a v2 server still emits in the old format, so a
/// v1 client probing it reads a clean version-mismatch message instead
/// of bytes it cannot parse.
pub fn encode_legacy_error(out: &mut Vec<u8>, msg: &str) {
    out.clear();
    out.push(V1_OP_ERROR);
    let bytes = msg.as_bytes();
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

/// Encode a `Shutdown` request (no body); the server acks, then exits.
pub fn encode_shutdown(out: &mut Vec<u8>, wave_id: u64) {
    put_head(out, OP_SHUTDOWN, wave_id);
}

/// Encode an `Ack` reply (no body).
pub fn encode_ack(out: &mut Vec<u8>, wave_id: u64) {
    put_head(out, OP_ACK, wave_id);
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

/// One sub-request of a decoded [`Message::PullBatch`] wave.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// the query vector this sub-request's bandit is serving
    pub query: Vec<f32>,
    /// dataset rows to pull, as **global** row ids
    pub rows: Vec<u32>,
    /// shared coordinate draws for every row of this sub-request
    pub coord_ids: Vec<u32>,
}

/// A decoded wire message (owned). Clients encode straight from borrowed
/// slices via the `encode_*` helpers; `Message::encode` delegates to the
/// same helpers so there is exactly one byte layout. Every variant
/// carries the frame's `wave_id` tag.
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // variant payloads are specified by the encoders
pub enum Message {
    /// Handshake request: the client's protocol version.
    Hello { wave_id: u64, version: u32 },
    /// Handshake reply: server version, dataset shape, owned row range,
    /// dataset fingerprint, serving placement epoch.
    HelloAck {
        wave_id: u64,
        version: u32,
        n_total: u64,
        d: u64,
        row_start: u64,
        row_end: u64,
        data_hash: u64,
        epoch: u64,
    },
    /// Single-query partial-moment wave (global row ids).
    PartialSums {
        wave_id: u64,
        metric: Metric,
        query: Vec<f32>,
        rows: Vec<u32>,
        coord_ids: Vec<u32>,
    },
    /// Exact-distance wave (global row ids).
    ExactDists {
        wave_id: u64,
        metric: Metric,
        query: Vec<f32>,
        rows: Vec<u32>,
    },
    /// Coalesced multi-query wave.
    PullBatch { wave_id: u64, metric: Metric, reqs: Vec<WireRequest> },
    /// Reply to `PartialSums` / `PullBatch`: per-job (Σx, Σx²),
    /// concatenated request-major.
    Sums { wave_id: u64, sum: Vec<f64>, sq: Vec<f64> },
    /// Reply to `ExactDists`: one distance per requested row.
    Dists { wave_id: u64, vals: Vec<f64> },
    /// Failure reply — also the client's failover trigger.
    Error { wave_id: u64, msg: String },
    /// Stop-serving request (no body); acked, then the server exits.
    Shutdown { wave_id: u64 },
    /// Generic acknowledgement (no body).
    Ack { wave_id: u64 },
    /// Health request (no body).
    Stats { wave_id: u64 },
    /// Health reply: shard identity, shape, row range, connection
    /// count, dataset fingerprint, per-connection wave high-water mark,
    /// serving placement epoch.
    StatsReply {
        wave_id: u64,
        shard: u64,
        of: u64,
        n_total: u64,
        d: u64,
        row_start: u64,
        row_end: u64,
        live_conns: u64,
        data_hash: u64,
        max_conn_waves: u64,
        epoch: u64,
    },
    /// Reshard stream announcement: the shard assignment (identity,
    /// shape, row range, target epoch) the following `TransferRows`
    /// chunks belong to. Replaces any pending transfer on the target.
    TransferBegin {
        wave_id: u64,
        shard: u64,
        of: u64,
        n_total: u64,
        d: u64,
        row_start: u64,
        row_end: u64,
        epoch: u64,
    },
    /// One chunk of streamed rows at `row_offset` rows into the
    /// announced range (whole rows; length a multiple of `d`).
    TransferRows { wave_id: u64, row_offset: u64, data: Vec<f32> },
    /// Commit request: the sender's fingerprint of the streamed range.
    /// The target verifies and installs (`Ack`) or rejects (`Error`).
    TransferCommit { wave_id: u64, data_hash: u64 },
}

struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| "length overflow".to_string())?;
        if end > self.b.len() {
            return Err(format!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            ));
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn u32s(&mut self) -> Result<Vec<u32>, String> {
        let n = self.u32()? as usize;
        let s = self.take(n.checked_mul(4).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        let s = self.take(n.checked_mul(4).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f64s_n(&mut self, n: usize) -> Result<Vec<f64>, String> {
        let s = self.take(n.checked_mul(8).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.u32()? as usize;
        self.f64s_n(n)
    }

    fn done(self) -> Result<(), String> {
        if self.pos != self.b.len() {
            return Err(format!("{} trailing bytes", self.b.len() - self.pos));
        }
        Ok(())
    }
}

impl Message {
    /// Short tag for diagnostics (no payload dump).
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::HelloAck { .. } => "hello_ack",
            Message::PartialSums { .. } => "partial_sums",
            Message::ExactDists { .. } => "exact_dists",
            Message::PullBatch { .. } => "pull_batch",
            Message::Sums { .. } => "sums",
            Message::Dists { .. } => "dists",
            Message::Error { .. } => "error",
            Message::Shutdown { .. } => "shutdown",
            Message::Ack { .. } => "ack",
            Message::Stats { .. } => "stats",
            Message::StatsReply { .. } => "stats_reply",
            Message::TransferBegin { .. } => "transfer_begin",
            Message::TransferRows { .. } => "transfer_rows",
            Message::TransferCommit { .. } => "transfer_commit",
        }
    }

    /// The frame's wave tag — what the demultiplexing reader routes
    /// replies by.
    pub fn wave_id(&self) -> u64 {
        match self {
            Message::Hello { wave_id, .. }
            | Message::HelloAck { wave_id, .. }
            | Message::PartialSums { wave_id, .. }
            | Message::ExactDists { wave_id, .. }
            | Message::PullBatch { wave_id, .. }
            | Message::Sums { wave_id, .. }
            | Message::Dists { wave_id, .. }
            | Message::Error { wave_id, .. }
            | Message::Shutdown { wave_id }
            | Message::Ack { wave_id }
            | Message::Stats { wave_id }
            | Message::StatsReply { wave_id, .. }
            | Message::TransferBegin { wave_id, .. }
            | Message::TransferRows { wave_id, .. }
            | Message::TransferCommit { wave_id, .. } => *wave_id,
        }
    }

    /// Encode into `out` (cleared first) — delegates to the borrowed
    /// `encode_*` helpers so both paths share one layout.
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Message::Hello { wave_id, version } => {
                encode_hello(out, *wave_id, *version)
            }
            Message::HelloAck {
                wave_id, version, n_total, d, row_start, row_end, data_hash,
                epoch,
            } => encode_hello_ack(out, *wave_id, *version, *n_total, *d,
                                  *row_start, *row_end, *data_hash, *epoch),
            Message::PartialSums { wave_id, metric, query, rows,
                                   coord_ids } => {
                encode_partial_sums(out, *wave_id, *metric, query, rows,
                                    coord_ids)
            }
            Message::ExactDists { wave_id, metric, query, rows } => {
                encode_exact_dists(out, *wave_id, *metric, query, rows)
            }
            Message::PullBatch { wave_id, metric, reqs } => {
                let views: Vec<PullRequest> = reqs
                    .iter()
                    .map(|r| PullRequest {
                        query: &r.query,
                        rows: &r.rows,
                        coord_ids: &r.coord_ids,
                    })
                    .collect();
                encode_pull_batch(out, *wave_id, *metric, &views);
            }
            Message::Sums { wave_id, sum, sq } => {
                encode_sums(out, *wave_id, sum, sq)
            }
            Message::Dists { wave_id, vals } => {
                encode_dists(out, *wave_id, vals)
            }
            Message::Error { wave_id, msg } => {
                encode_error(out, *wave_id, msg)
            }
            Message::Shutdown { wave_id } => encode_shutdown(out, *wave_id),
            Message::Ack { wave_id } => encode_ack(out, *wave_id),
            Message::Stats { wave_id } => encode_stats(out, *wave_id),
            Message::StatsReply {
                wave_id, shard, of, n_total, d, row_start, row_end,
                live_conns, data_hash, max_conn_waves, epoch,
            } => encode_stats_reply(out, *wave_id, *shard, *of, *n_total,
                                    *d, *row_start, *row_end, *live_conns,
                                    *data_hash, *max_conn_waves, *epoch),
            Message::TransferBegin {
                wave_id, shard, of, n_total, d, row_start, row_end, epoch,
            } => encode_transfer_begin(out, *wave_id, *shard, *of, *n_total,
                                       *d, *row_start, *row_end, *epoch),
            Message::TransferRows { wave_id, row_offset, data } => {
                encode_transfer_rows(out, *wave_id, *row_offset, data)
            }
            Message::TransferCommit { wave_id, data_hash } => {
                encode_transfer_commit(out, *wave_id, *data_hash)
            }
        }
    }

    /// Decode one payload. Rejects truncation, trailing bytes, unknown
    /// opcodes, bad metric codes, v1 (untagged) frames and retired v2
    /// reply opcodes — the version'd rejections with an explicit
    /// version-mismatch error; never panics on malformed input.
    pub fn decode(payload: &[u8]) -> Result<Message, String> {
        let mut c = Cur { b: payload, pos: 0 };
        let op = c.u8().map_err(|_| "empty frame".to_string())?;
        if (V1_OP_MIN..=V1_OP_MAX).contains(&op) {
            return Err(format!(
                "protocol version mismatch: peer sent a v1 (untagged) \
                 frame, opcode {op}; this build speaks wire protocol \
                 v{PROTOCOL_VERSION} (wave-tagged frames) — upgrade the \
                 peer"));
        }
        if op == V2_OP_HELLO_ACK || op == V2_OP_STATS_REPLY {
            return Err(format!(
                "protocol version mismatch: peer sent retired v2 opcode \
                 {op} (its layout gained a placement epoch in v3); this \
                 build speaks wire protocol v{PROTOCOL_VERSION} — \
                 upgrade the peer"));
        }
        let wave_id = c.u64()?;
        let msg = match op {
            OP_HELLO => Message::Hello { wave_id, version: c.u32()? },
            OP_HELLO_ACK => Message::HelloAck {
                wave_id,
                version: c.u32()?,
                n_total: c.u64()?,
                d: c.u64()?,
                row_start: c.u64()?,
                row_end: c.u64()?,
                data_hash: c.u64()?,
                epoch: c.u64()?,
            },
            OP_PARTIAL_SUMS => {
                let metric = metric_from(c.u8()?)?;
                Message::PartialSums {
                    wave_id,
                    metric,
                    query: c.f32s()?,
                    rows: c.u32s()?,
                    coord_ids: c.u32s()?,
                }
            }
            OP_EXACT_DISTS => {
                let metric = metric_from(c.u8()?)?;
                Message::ExactDists {
                    wave_id,
                    metric,
                    query: c.f32s()?,
                    rows: c.u32s()?,
                }
            }
            OP_PULL_BATCH => {
                let metric = metric_from(c.u8()?)?;
                let n = c.u32()? as usize;
                // each sub-request needs at least its three length words:
                // a count beyond that bound is a corrupt header
                if n > payload.len() / 12 + 1 {
                    return Err(format!("pull_batch count {n} exceeds frame"));
                }
                // reservation stays modest even for a hostile count that
                // passed the bound — growth is paid only as sub-requests
                // actually parse (each consumes >= 12 payload bytes)
                let mut reqs = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    reqs.push(WireRequest {
                        query: c.f32s()?,
                        rows: c.u32s()?,
                        coord_ids: c.u32s()?,
                    });
                }
                Message::PullBatch { wave_id, metric, reqs }
            }
            OP_SUMS => {
                let n = c.u32()? as usize;
                let sum = c.f64s_n(n)?;
                let sq = c.f64s_n(n)?;
                Message::Sums { wave_id, sum, sq }
            }
            OP_DISTS => Message::Dists { wave_id, vals: c.f64s()? },
            OP_ERROR => {
                let n = c.u32()? as usize;
                let bytes = c.take(n)?;
                Message::Error {
                    wave_id,
                    msg: String::from_utf8_lossy(bytes).into_owned(),
                }
            }
            OP_SHUTDOWN => Message::Shutdown { wave_id },
            OP_ACK => Message::Ack { wave_id },
            OP_STATS => Message::Stats { wave_id },
            OP_STATS_REPLY => Message::StatsReply {
                wave_id,
                shard: c.u64()?,
                of: c.u64()?,
                n_total: c.u64()?,
                d: c.u64()?,
                row_start: c.u64()?,
                row_end: c.u64()?,
                live_conns: c.u64()?,
                data_hash: c.u64()?,
                max_conn_waves: c.u64()?,
                epoch: c.u64()?,
            },
            OP_TRANSFER_BEGIN => Message::TransferBegin {
                wave_id,
                shard: c.u64()?,
                of: c.u64()?,
                n_total: c.u64()?,
                d: c.u64()?,
                row_start: c.u64()?,
                row_end: c.u64()?,
                epoch: c.u64()?,
            },
            OP_TRANSFER_ROWS => Message::TransferRows {
                wave_id,
                row_offset: c.u64()?,
                // f32s() pays allocation only as received bytes justify
                // it (`take` bounds the count), same as every other
                // vector field — a forged chunk count cannot allocate
                data: c.f32s()?,
            },
            OP_TRANSFER_COMMIT => Message::TransferCommit {
                wave_id,
                data_hash: c.u64()?,
            },
            x => return Err(format!("unknown opcode {x}")),
        };
        c.done()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Write one `u32 len | payload` frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame into `buf`. A declared length beyond [`MAX_FRAME`] is
/// rejected before allocating, and the buffer grows only as bytes
/// actually arrive — a forged length header cannot force a huge up-front
/// allocation from a peer that never sends the payload.
pub fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut len_b = [0u8; 4];
    r.read_exact(&mut len_b)?;
    let len = u32::from_le_bytes(len_b) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME"),
        ));
    }
    buf.clear();
    let got = r.by_ref().take(len as u64).read_to_end(buf)?;
    if got < len {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!("truncated frame: {got} of {len} bytes"),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn deadline_errors_classify_through_wrapping() {
        assert!(is_deadline_error(DEADLINE_ERROR));
        assert!(is_deadline_error(
            "remote pull wave failed: deadline exceeded: shard 1: \
             query budget exhausted"));
        assert!(!is_deadline_error("shard 1: no live replica: refused"));
        assert!(!is_deadline_error("request timed out"));
        assert!(!is_deadline_error(""));
    }

    fn arb_f32s(rng: &mut Rng) -> Vec<f32> {
        let n = rng.below(20); // 0..=19 — empty slices included
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    fn arb_u32s(rng: &mut Rng) -> Vec<u32> {
        let n = rng.below(20);
        (0..n).map(|_| rng.below(1 << 20) as u32).collect()
    }

    fn arb_f64s(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.gaussian()).collect()
    }

    fn arb_metric(rng: &mut Rng) -> Metric {
        if rng.bool(0.5) { Metric::L2Sq } else { Metric::L1 }
    }

    fn arb_msg(rng: &mut Rng) -> Message {
        let wave_id = rng.next_u64();
        match rng.below(15) {
            10 => Message::Stats { wave_id },
            11 => Message::StatsReply {
                wave_id,
                shard: rng.next_u64(),
                of: rng.next_u64(),
                n_total: rng.next_u64(),
                d: rng.next_u64(),
                row_start: rng.next_u64(),
                row_end: rng.next_u64(),
                live_conns: rng.next_u64(),
                data_hash: rng.next_u64(),
                max_conn_waves: rng.next_u64(),
                epoch: rng.next_u64(),
            },
            12 => Message::TransferBegin {
                wave_id,
                shard: rng.next_u64(),
                of: rng.next_u64(),
                n_total: rng.next_u64(),
                d: rng.next_u64(),
                row_start: rng.next_u64(),
                row_end: rng.next_u64(),
                epoch: rng.next_u64(),
            },
            13 => Message::TransferRows {
                wave_id,
                row_offset: rng.next_u64(),
                data: arb_f32s(rng),
            },
            14 => Message::TransferCommit {
                wave_id,
                data_hash: rng.next_u64(),
            },
            0 => Message::Hello { wave_id,
                                  version: rng.below(1 << 30) as u32 },
            1 => Message::HelloAck {
                wave_id,
                version: rng.below(1 << 30) as u32,
                n_total: rng.next_u64(),
                d: rng.next_u64(),
                row_start: rng.next_u64(),
                row_end: rng.next_u64(),
                data_hash: rng.next_u64(),
                epoch: rng.next_u64(),
            },
            2 => Message::PartialSums {
                wave_id,
                metric: arb_metric(rng),
                query: arb_f32s(rng),
                rows: arb_u32s(rng),
                coord_ids: arb_u32s(rng),
            },
            3 => Message::ExactDists {
                wave_id,
                metric: arb_metric(rng),
                query: arb_f32s(rng),
                rows: arb_u32s(rng),
            },
            4 => {
                let n = rng.below(5); // empty waves included
                Message::PullBatch {
                    wave_id,
                    metric: arb_metric(rng),
                    reqs: (0..n)
                        .map(|_| WireRequest {
                            query: arb_f32s(rng),
                            rows: arb_u32s(rng),
                            coord_ids: arb_u32s(rng),
                        })
                        .collect(),
                }
            }
            5 => {
                let n = rng.below(16);
                Message::Sums {
                    wave_id,
                    sum: arb_f64s(rng, n),
                    sq: arb_f64s(rng, n),
                }
            }
            6 => {
                let n = rng.below(16);
                Message::Dists { wave_id, vals: arb_f64s(rng, n) }
            }
            7 => Message::Error {
                wave_id,
                msg: format!("e{}", rng.below(1000)),
            },
            8 => Message::Shutdown { wave_id },
            _ => Message::Ack { wave_id },
        }
    }

    #[test]
    fn encode_decode_roundtrips_arbitrary_messages() {
        proptest::check(400, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            let got = Message::decode(&buf)
                .map_err(|e| format!("{} failed to decode: {e}",
                                     msg.kind()))?;
            crate::prop_assert!(got == msg,
                                "{} did not round-trip", msg.kind());
            crate::prop_assert!(got.wave_id() == msg.wave_id(),
                                "{} wave tag did not survive", msg.kind());
            crate::prop_assert!(peek_wave_id(&buf) == msg.wave_id(),
                                "peek_wave_id disagrees with decode");
            Ok(())
        });
    }

    #[test]
    fn every_strict_prefix_is_rejected_without_panicking() {
        proptest::check(120, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            for cut in 0..buf.len() {
                crate::prop_assert!(
                    Message::decode(&buf[..cut]).is_err(),
                    "{} truncated to {cut}/{} bytes decoded",
                    msg.kind(),
                    buf.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        proptest::check(80, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            buf.push(0);
            crate::prop_assert!(Message::decode(&buf).is_err(),
                                "{} accepted a trailing byte", msg.kind());
            Ok(())
        });
    }

    #[test]
    fn client_encoders_match_owned_message_encoding() {
        // one byte layout: the borrowed hot-path encoders and
        // Message::encode must agree (they delegate, this pins it)
        let query = vec![1.5f32, -2.0, 0.25];
        let rows = vec![7u32, 3];
        let coords = vec![0u32, 2, 2];
        let mut a = Vec::new();
        encode_partial_sums(&mut a, 42, Metric::L1, &query, &rows, &coords);
        let mut b = Vec::new();
        Message::PartialSums {
            wave_id: 42,
            metric: Metric::L1,
            query: query.clone(),
            rows: rows.clone(),
            coord_ids: coords.clone(),
        }
        .encode(&mut b);
        assert_eq!(a, b);
        let req = PullRequest { query: &query, rows: &rows,
                                coord_ids: &coords };
        encode_pull_batch(&mut a, 7, Metric::L2Sq, &[req]);
        Message::PullBatch {
            wave_id: 7,
            metric: Metric::L2Sq,
            reqs: vec![WireRequest { query, rows, coord_ids: coords }],
        }
        .encode(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn bad_opcode_and_bad_metric_are_rejected() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // PartialSums with metric code 7 (tag present, body malformed)
        let mut bad = Vec::new();
        bad.push(103u8);
        bad.extend_from_slice(&5u64.to_le_bytes());
        bad.push(7); // bad metric
        bad.extend_from_slice(&0u32.to_le_bytes());
        assert!(Message::decode(&bad).is_err());
    }

    #[test]
    fn v1_frames_are_rejected_with_a_version_error() {
        // every v1 opcode — including old Hello [1] and Error [8] — must
        // produce the explicit version-mismatch error, not "unknown
        // opcode" and never a panic
        for op in 1u8..=12 {
            let err = Message::decode(&[op]).unwrap_err();
            assert!(err.contains("version mismatch"),
                    "op {op}: got '{err}'");
            assert!(err.contains("v1"), "op {op}: got '{err}'");
        }
        assert!(is_legacy_frame(&[1]));
        assert!(is_legacy_frame(&[12, 0, 0]));
        assert!(!is_legacy_frame(&[101]));
        assert!(!is_legacy_frame(&[]));
        // the legacy error frame a v2 server answers v1 peers with is
        // valid v1 bytes: op 8, u32 len, message
        let mut out = Vec::new();
        encode_legacy_error(&mut out, "nope");
        assert_eq!(out[0], 8);
        assert_eq!(u32::from_le_bytes(out[1..5].try_into().unwrap()), 4);
        assert_eq!(&out[5..], b"nope");
        // and a v2 decoder reports it as a version mismatch too
        assert!(Message::decode(&out).unwrap_err()
                .contains("version mismatch"));
    }

    #[test]
    fn retired_v2_frames_are_rejected_with_a_version_error() {
        // the two v2 reply opcodes whose layouts grew an epoch field:
        // their old opcodes must answer an explicit version mismatch —
        // not "unknown opcode", and crucially not "truncated frame"
        // (the check runs before the wave-tag parse, so even a bare
        // opcode byte from a confused v2 peer names the real problem)
        for op in [102u8, 112] {
            for frame in [vec![op], {
                let mut f = vec![op];
                f.extend_from_slice(&7u64.to_le_bytes());
                f.extend_from_slice(&[0u8; 48]);
                f
            }] {
                let err = Message::decode(&frame).unwrap_err();
                assert!(err.contains("version mismatch"),
                        "op {op}: got '{err}'");
                assert!(err.contains("v2"), "op {op}: got '{err}'");
            }
        }
        // the v3 replacements decode fine (not caught by the check)
        let mut buf = Vec::new();
        encode_hello_ack(&mut buf, 1, PROTOCOL_VERSION, 10, 4, 0, 5, 9, 2);
        match Message::decode(&buf).unwrap() {
            Message::HelloAck { epoch, .. } => assert_eq!(epoch, 2),
            other => panic!("unexpected {}", other.kind()),
        }
        encode_stats_reply(&mut buf, 1, 0, 2, 10, 4, 0, 5, 1, 9, 3, 7);
        match Message::decode(&buf).unwrap() {
            Message::StatsReply { epoch, .. } => assert_eq!(epoch, 7),
            other => panic!("unexpected {}", other.kind()),
        }
        // retired opcodes are not "legacy" (v1) frames — the v1 error
        // framing is reserved for actual v1 peers
        assert!(!is_legacy_frame(&[102]));
        assert!(!is_legacy_frame(&[112]));
    }

    #[test]
    fn transfer_stream_roundtrips_with_exact_float_bits() {
        // the reshard stream moves dataset bytes; like Dists, odd f32
        // bit patterns must survive exactly or the fingerprint check
        // at commit would reject a correct transfer
        let vals = vec![-0.0f32, f32::INFINITY, 1e-42, -3.5];
        let mut buf = Vec::new();
        encode_transfer_rows(&mut buf, 9, 128, &vals);
        match Message::decode(&buf).unwrap() {
            Message::TransferRows { wave_id, row_offset, data } => {
                assert_eq!((wave_id, row_offset), (9, 128));
                for (a, b) in vals.iter().zip(&data) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {}", other.kind()),
        }
        encode_transfer_begin(&mut buf, 5, 3, 4, 100, 16, 75, 100, 2);
        match Message::decode(&buf).unwrap() {
            Message::TransferBegin { shard, of, row_start, row_end,
                                     epoch, .. } => {
                assert_eq!((shard, of), (3, 4));
                assert_eq!((row_start, row_end, epoch), (75, 100, 2));
            }
            other => panic!("unexpected {}", other.kind()),
        }
        encode_transfer_commit(&mut buf, 11, 0xfeed);
        match Message::decode(&buf).unwrap() {
            Message::TransferCommit { wave_id, data_hash } => {
                assert_eq!((wave_id, data_hash), (11, 0xfeed));
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn peek_wave_id_survives_body_corruption() {
        let mut buf = Vec::new();
        encode_stats(&mut buf, 0xDEAD_BEEF);
        buf.push(99); // trailing garbage: decode fails…
        assert!(Message::decode(&buf).is_err());
        // …but the tag is still recoverable for the error reply
        assert_eq!(peek_wave_id(&buf), 0xDEAD_BEEF);
        assert_eq!(peek_wave_id(&[101, 1]), 0, "short frame peeks as 0");
    }

    #[test]
    fn frames_roundtrip_and_oversized_headers_are_rejected() {
        let payload = vec![1u8, 2, 3, 4, 5];
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &payload).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        let mut got = Vec::new();
        read_frame(&mut cur, &mut got).unwrap();
        assert_eq!(got, payload);
        // forged header claiming a 2 GiB payload: rejected, no allocation
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let mut cur = std::io::Cursor::new(huge);
        let err = read_frame(&mut cur, &mut got).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // truncated stream: header promises more than arrives
        let mut short = 10u32.to_le_bytes().to_vec();
        short.extend_from_slice(&[1, 2, 3]);
        let mut cur = std::io::Cursor::new(short);
        assert!(read_frame(&mut cur, &mut got).is_err());
    }

    #[test]
    fn float_bits_survive_the_wire_exactly() {
        // bitwise parity across the network hinges on this: encode odd
        // bit patterns (negative zero, subnormals, inf) and compare bits
        let vals = vec![-0.0f64, f64::INFINITY, 1e-310, -3.5];
        let mut buf = Vec::new();
        encode_dists(&mut buf, 3, &vals);
        match Message::decode(&buf).unwrap() {
            Message::Dists { wave_id, vals: got } => {
                assert_eq!(wave_id, 3);
                for (a, b) in vals.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn dataset_fingerprint_tracks_content_shape_and_placement() {
        use crate::data::synthetic;
        let a = synthetic::gaussian_iid(6, 4, 1);
        let b = synthetic::gaussian_iid(6, 4, 1);
        let c = synthetic::gaussian_iid(6, 4, 2);
        // identical content (replicas of one shard) agree
        assert_eq!(dataset_fingerprint(12, 3, &a),
                   dataset_fingerprint(12, 3, &b));
        // different rows (a diverged replica) disagree
        assert_ne!(dataset_fingerprint(12, 3, &a),
                   dataset_fingerprint(12, 3, &c));
        // same rows at a different placement disagree too — a replica
        // serving the right bytes as the wrong shard is still wrong
        assert_ne!(dataset_fingerprint(12, 3, &a),
                   dataset_fingerprint(12, 0, &a));
        assert_ne!(dataset_fingerprint(12, 3, &a),
                   dataset_fingerprint(24, 3, &a));
    }
}
