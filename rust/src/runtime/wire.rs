//! Length-prefixed binary wire protocol for network-distributed pull
//! execution (`runtime::remote`).
//!
//! Framing: every message travels as `u32 payload_len (LE) | payload`,
//! where `payload[0]` is an opcode byte and the rest is a fixed-layout
//! little-endian body. [`read_frame`] rejects frames whose declared
//! length exceeds [`MAX_FRAME`] *before* allocating, and
//! [`Message::decode`] rejects truncated payloads, trailing garbage,
//! unknown opcodes and bad metric codes with an `Err` — never a panic
//! (property-tested below: every strict prefix of a valid payload fails
//! to decode).
//!
//! Requests (coordinator → shard server):
//! * `Hello` — handshake; the server answers [`Message::HelloAck`] with
//!   the global dataset shape and the row range it owns, which lets the
//!   client prove the ring tiles the dataset with the same floor-boundary
//!   partition the in-process sharded engine uses
//!   (`runtime::partition::shard_range`).
//! * `Stats` — the health op: like `Hello` it carries no body and may be
//!   sent at any point on a connection. The server answers
//!   [`Message::StatsReply`] with its shard identity (`shard` of `of`),
//!   dataset shape, owned row range and live-connection count, so a
//!   coordinator can discover how a ring is laid out (and size
//!   `--remote` accordingly) by probing endpoints — see the
//!   `bmonn ring-stats` subcommand.
//! * `PartialSums` / `ExactDists` / `PullBatch` — one engine wave, rows
//!   given as **global** ids; the server rebases them onto its local
//!   row range and rejects anything outside it.
//! * `Shutdown` — acked with [`Message::Ack`], then the server exits.
//!
//! Replies (shard server → coordinator): `HelloAck`, `StatsReply`,
//! `Sums { sum, sq }` (for `PartialSums` and `PullBatch`, concatenated
//! request-major), `Dists { vals }`, `Error { msg }`, `Ack`.
//!
//! An `Error` reply is also a failover trigger: the replicated client
//! (`runtime::remote::RemoteEngine`) re-issues the sub-wave to the
//! shard's next live replica (without blacklisting the answering
//! server — its connection is healthy, only the request failed).
//!
//! All floats cross the wire via `to_le_bytes`/`from_le_bytes`, i.e. by
//! exact bit pattern — the transport can never perturb the bitwise
//! parity the engines are pinned to.
//!
//! The byte-level layout of every message is specified normatively in
//! `docs/WIRE_PROTOCOL.md`.

#![deny(missing_docs)]

use std::io::{self, Read, Write};

use crate::coordinator::arms::PullRequest;
use crate::data::dense::Metric;

/// Hard cap on a single frame's payload (1 GiB). A real wave is far
/// smaller (a 4M-job reply is ~64 MiB); a length header beyond this is a
/// corrupt or hostile stream and is rejected before any allocation.
pub const MAX_FRAME: usize = 1 << 30;

const OP_HELLO: u8 = 1;
const OP_HELLO_ACK: u8 = 2;
const OP_PARTIAL_SUMS: u8 = 3;
const OP_EXACT_DISTS: u8 = 4;
const OP_PULL_BATCH: u8 = 5;
const OP_SUMS: u8 = 6;
const OP_DISTS: u8 = 7;
const OP_ERROR: u8 = 8;
const OP_SHUTDOWN: u8 = 9;
const OP_ACK: u8 = 10;
const OP_STATS: u8 = 11;
const OP_STATS_REPLY: u8 = 12;

fn metric_code(m: Metric) -> u8 {
    match m {
        Metric::L2Sq => 0,
        Metric::L1 => 1,
    }
}

fn metric_from(code: u8) -> Result<Metric, String> {
    match code {
        0 => Ok(Metric::L2Sq),
        1 => Ok(Metric::L1),
        x => Err(format!("bad metric code {x}")),
    }
}

// ---------------------------------------------------------------------
// encoding — each `encode_*` clears `out` and writes one full payload;
// the client-side helpers take borrowed slices so the hot path never
// copies a wave into an owned message first
// ---------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(out: &mut Vec<u8>, xs: &[f64]) {
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a `Hello` handshake request (no body).
pub fn encode_hello(out: &mut Vec<u8>) {
    out.clear();
    out.push(OP_HELLO);
}

/// Encode the `HelloAck` handshake reply: global dataset shape plus the
/// row range `[row_start, row_end)` this server owns.
pub fn encode_hello_ack(out: &mut Vec<u8>, n_total: u64, d: u64,
                        row_start: u64, row_end: u64) {
    out.clear();
    out.push(OP_HELLO_ACK);
    put_u64(out, n_total);
    put_u64(out, d);
    put_u64(out, row_start);
    put_u64(out, row_end);
}

/// Encode a `Stats` health request (no body).
pub fn encode_stats(out: &mut Vec<u8>) {
    out.clear();
    out.push(OP_STATS);
}

/// Encode a `StatsReply`: shard identity (`shard` of `of`), dataset
/// shape, owned row range, and the server's live-connection count.
pub fn encode_stats_reply(out: &mut Vec<u8>, shard: u64, of: u64,
                          n_total: u64, d: u64, row_start: u64,
                          row_end: u64, live_conns: u64) {
    out.clear();
    out.push(OP_STATS_REPLY);
    put_u64(out, shard);
    put_u64(out, of);
    put_u64(out, n_total);
    put_u64(out, d);
    put_u64(out, row_start);
    put_u64(out, row_end);
    put_u64(out, live_conns);
}

/// Encode a `PartialSums` wave request from borrowed slices (rows are
/// global ids).
pub fn encode_partial_sums(out: &mut Vec<u8>, metric: Metric,
                           query: &[f32], rows: &[u32],
                           coord_ids: &[u32]) {
    out.clear();
    out.push(OP_PARTIAL_SUMS);
    out.push(metric_code(metric));
    put_f32s(out, query);
    put_u32s(out, rows);
    put_u32s(out, coord_ids);
}

/// Encode an `ExactDists` wave request from borrowed slices.
pub fn encode_exact_dists(out: &mut Vec<u8>, metric: Metric, query: &[f32],
                          rows: &[u32]) {
    out.clear();
    out.push(OP_EXACT_DISTS);
    out.push(metric_code(metric));
    put_f32s(out, query);
    put_u32s(out, rows);
}

/// Encode a `PullBatch` wave request straight from the coordinator's
/// borrowed [`PullRequest`] views (the hot path never copies a wave into
/// an owned message first).
pub fn encode_pull_batch(out: &mut Vec<u8>, metric: Metric,
                         reqs: &[PullRequest<'_>]) {
    out.clear();
    out.push(OP_PULL_BATCH);
    out.push(metric_code(metric));
    put_u32(out, reqs.len() as u32);
    for r in reqs {
        put_f32s(out, r.query);
        put_u32s(out, r.rows);
        put_u32s(out, r.coord_ids);
    }
}

/// `sum` and `sq` must have equal length (one shared count on the wire).
pub fn encode_sums(out: &mut Vec<u8>, sum: &[f64], sq: &[f64]) {
    assert_eq!(sum.len(), sq.len());
    out.clear();
    out.push(OP_SUMS);
    put_u32(out, sum.len() as u32);
    for &x in sum {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in sq {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a `Dists` reply (exact distances, one per requested row).
pub fn encode_dists(out: &mut Vec<u8>, vals: &[f64]) {
    out.clear();
    out.push(OP_DISTS);
    put_f64s(out, vals);
}

/// Encode an `Error` reply carrying a human-readable message.
pub fn encode_error(out: &mut Vec<u8>, msg: &str) {
    out.clear();
    out.push(OP_ERROR);
    let bytes = msg.as_bytes();
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

/// Encode a `Shutdown` request (no body); the server acks, then exits.
pub fn encode_shutdown(out: &mut Vec<u8>) {
    out.clear();
    out.push(OP_SHUTDOWN);
}

/// Encode an `Ack` reply (no body).
pub fn encode_ack(out: &mut Vec<u8>) {
    out.clear();
    out.push(OP_ACK);
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

/// One sub-request of a decoded [`Message::PullBatch`] wave.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    /// the query vector this sub-request's bandit is serving
    pub query: Vec<f32>,
    /// dataset rows to pull, as **global** row ids
    pub rows: Vec<u32>,
    /// shared coordinate draws for every row of this sub-request
    pub coord_ids: Vec<u32>,
}

/// A decoded wire message (owned). Clients encode straight from borrowed
/// slices via the `encode_*` helpers; `Message::encode` delegates to the
/// same helpers so there is exactly one byte layout.
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // variant payloads are specified by the encoders
pub enum Message {
    /// Handshake request (no body).
    Hello,
    /// Handshake reply: dataset shape + owned row range.
    HelloAck { n_total: u64, d: u64, row_start: u64, row_end: u64 },
    /// Single-query partial-moment wave (global row ids).
    PartialSums {
        metric: Metric,
        query: Vec<f32>,
        rows: Vec<u32>,
        coord_ids: Vec<u32>,
    },
    /// Exact-distance wave (global row ids).
    ExactDists { metric: Metric, query: Vec<f32>, rows: Vec<u32> },
    /// Coalesced multi-query wave.
    PullBatch { metric: Metric, reqs: Vec<WireRequest> },
    /// Reply to `PartialSums` / `PullBatch`: per-job (Σx, Σx²),
    /// concatenated request-major.
    Sums { sum: Vec<f64>, sq: Vec<f64> },
    /// Reply to `ExactDists`: one distance per requested row.
    Dists { vals: Vec<f64> },
    /// Failure reply — also the client's failover trigger.
    Error { msg: String },
    /// Stop-serving request (no body); acked, then the server exits.
    Shutdown,
    /// Generic acknowledgement (no body).
    Ack,
    /// Health request (no body).
    Stats,
    /// Health reply: shard identity, shape, row range, connection count.
    StatsReply {
        shard: u64,
        of: u64,
        n_total: u64,
        d: u64,
        row_start: u64,
        row_end: u64,
        live_conns: u64,
    },
}

struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| "length overflow".to_string())?;
        if end > self.b.len() {
            return Err(format!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.pos,
                self.b.len() - self.pos
            ));
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn u32s(&mut self) -> Result<Vec<u32>, String> {
        let n = self.u32()? as usize;
        let s = self.take(n.checked_mul(4).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.u32()? as usize;
        let s = self.take(n.checked_mul(4).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f64s_n(&mut self, n: usize) -> Result<Vec<f64>, String> {
        let s = self.take(n.checked_mul(8).ok_or("length overflow")?)?;
        Ok(s.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.u32()? as usize;
        self.f64s_n(n)
    }

    fn done(self) -> Result<(), String> {
        if self.pos != self.b.len() {
            return Err(format!("{} trailing bytes", self.b.len() - self.pos));
        }
        Ok(())
    }
}

impl Message {
    /// Short tag for diagnostics (no payload dump).
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Hello => "hello",
            Message::HelloAck { .. } => "hello_ack",
            Message::PartialSums { .. } => "partial_sums",
            Message::ExactDists { .. } => "exact_dists",
            Message::PullBatch { .. } => "pull_batch",
            Message::Sums { .. } => "sums",
            Message::Dists { .. } => "dists",
            Message::Error { .. } => "error",
            Message::Shutdown => "shutdown",
            Message::Ack => "ack",
            Message::Stats => "stats",
            Message::StatsReply { .. } => "stats_reply",
        }
    }

    /// Encode into `out` (cleared first) — delegates to the borrowed
    /// `encode_*` helpers so both paths share one layout.
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Message::Hello => encode_hello(out),
            Message::HelloAck { n_total, d, row_start, row_end } => {
                encode_hello_ack(out, *n_total, *d, *row_start, *row_end)
            }
            Message::PartialSums { metric, query, rows, coord_ids } => {
                encode_partial_sums(out, *metric, query, rows, coord_ids)
            }
            Message::ExactDists { metric, query, rows } => {
                encode_exact_dists(out, *metric, query, rows)
            }
            Message::PullBatch { metric, reqs } => {
                let views: Vec<PullRequest> = reqs
                    .iter()
                    .map(|r| PullRequest {
                        query: &r.query,
                        rows: &r.rows,
                        coord_ids: &r.coord_ids,
                    })
                    .collect();
                encode_pull_batch(out, *metric, &views);
            }
            Message::Sums { sum, sq } => encode_sums(out, sum, sq),
            Message::Dists { vals } => encode_dists(out, vals),
            Message::Error { msg } => encode_error(out, msg),
            Message::Shutdown => encode_shutdown(out),
            Message::Ack => encode_ack(out),
            Message::Stats => encode_stats(out),
            Message::StatsReply {
                shard, of, n_total, d, row_start, row_end, live_conns,
            } => encode_stats_reply(out, *shard, *of, *n_total, *d,
                                    *row_start, *row_end, *live_conns),
        }
    }

    /// Decode one payload. Rejects truncation, trailing bytes, unknown
    /// opcodes and bad metric codes; never panics on malformed input.
    pub fn decode(payload: &[u8]) -> Result<Message, String> {
        let mut c = Cur { b: payload, pos: 0 };
        let op = c.u8().map_err(|_| "empty frame".to_string())?;
        let msg = match op {
            OP_HELLO => Message::Hello,
            OP_HELLO_ACK => Message::HelloAck {
                n_total: c.u64()?,
                d: c.u64()?,
                row_start: c.u64()?,
                row_end: c.u64()?,
            },
            OP_PARTIAL_SUMS => {
                let metric = metric_from(c.u8()?)?;
                Message::PartialSums {
                    metric,
                    query: c.f32s()?,
                    rows: c.u32s()?,
                    coord_ids: c.u32s()?,
                }
            }
            OP_EXACT_DISTS => {
                let metric = metric_from(c.u8()?)?;
                Message::ExactDists {
                    metric,
                    query: c.f32s()?,
                    rows: c.u32s()?,
                }
            }
            OP_PULL_BATCH => {
                let metric = metric_from(c.u8()?)?;
                let n = c.u32()? as usize;
                // each sub-request needs at least its three length words:
                // a count beyond that bound is a corrupt header
                if n > payload.len() / 12 + 1 {
                    return Err(format!("pull_batch count {n} exceeds frame"));
                }
                // reservation stays modest even for a hostile count that
                // passed the bound — growth is paid only as sub-requests
                // actually parse (each consumes >= 12 payload bytes)
                let mut reqs = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    reqs.push(WireRequest {
                        query: c.f32s()?,
                        rows: c.u32s()?,
                        coord_ids: c.u32s()?,
                    });
                }
                Message::PullBatch { metric, reqs }
            }
            OP_SUMS => {
                let n = c.u32()? as usize;
                let sum = c.f64s_n(n)?;
                let sq = c.f64s_n(n)?;
                Message::Sums { sum, sq }
            }
            OP_DISTS => Message::Dists { vals: c.f64s()? },
            OP_ERROR => {
                let n = c.u32()? as usize;
                let bytes = c.take(n)?;
                Message::Error {
                    msg: String::from_utf8_lossy(bytes).into_owned(),
                }
            }
            OP_SHUTDOWN => Message::Shutdown,
            OP_ACK => Message::Ack,
            OP_STATS => Message::Stats,
            OP_STATS_REPLY => Message::StatsReply {
                shard: c.u64()?,
                of: c.u64()?,
                n_total: c.u64()?,
                d: c.u64()?,
                row_start: c.u64()?,
                row_end: c.u64()?,
                live_conns: c.u64()?,
            },
            x => return Err(format!("unknown opcode {x}")),
        };
        c.done()?;
        Ok(msg)
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Write one `u32 len | payload` frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame into `buf`. A declared length beyond [`MAX_FRAME`] is
/// rejected before allocating, and the buffer grows only as bytes
/// actually arrive — a forged length header cannot force a huge up-front
/// allocation from a peer that never sends the payload.
pub fn read_frame<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut len_b = [0u8; 4];
    r.read_exact(&mut len_b)?;
    let len = u32::from_le_bytes(len_b) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME"),
        ));
    }
    buf.clear();
    let got = r.by_ref().take(len as u64).read_to_end(buf)?;
    if got < len {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            format!("truncated frame: {got} of {len} bytes"),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn arb_f32s(rng: &mut Rng) -> Vec<f32> {
        let n = rng.below(20); // 0..=19 — empty slices included
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    fn arb_u32s(rng: &mut Rng) -> Vec<u32> {
        let n = rng.below(20);
        (0..n).map(|_| rng.below(1 << 20) as u32).collect()
    }

    fn arb_f64s(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.gaussian()).collect()
    }

    fn arb_metric(rng: &mut Rng) -> Metric {
        if rng.bool(0.5) { Metric::L2Sq } else { Metric::L1 }
    }

    fn arb_msg(rng: &mut Rng) -> Message {
        match rng.below(12) {
            10 => Message::Stats,
            11 => Message::StatsReply {
                shard: rng.next_u64(),
                of: rng.next_u64(),
                n_total: rng.next_u64(),
                d: rng.next_u64(),
                row_start: rng.next_u64(),
                row_end: rng.next_u64(),
                live_conns: rng.next_u64(),
            },
            0 => Message::Hello,
            1 => Message::HelloAck {
                n_total: rng.next_u64(),
                d: rng.next_u64(),
                row_start: rng.next_u64(),
                row_end: rng.next_u64(),
            },
            2 => Message::PartialSums {
                metric: arb_metric(rng),
                query: arb_f32s(rng),
                rows: arb_u32s(rng),
                coord_ids: arb_u32s(rng),
            },
            3 => Message::ExactDists {
                metric: arb_metric(rng),
                query: arb_f32s(rng),
                rows: arb_u32s(rng),
            },
            4 => {
                let n = rng.below(5); // empty waves included
                Message::PullBatch {
                    metric: arb_metric(rng),
                    reqs: (0..n)
                        .map(|_| WireRequest {
                            query: arb_f32s(rng),
                            rows: arb_u32s(rng),
                            coord_ids: arb_u32s(rng),
                        })
                        .collect(),
                }
            }
            5 => {
                let n = rng.below(16);
                Message::Sums {
                    sum: arb_f64s(rng, n),
                    sq: arb_f64s(rng, n),
                }
            }
            6 => {
                let n = rng.below(16);
                Message::Dists { vals: arb_f64s(rng, n) }
            }
            7 => Message::Error {
                msg: format!("e{}", rng.below(1000)),
            },
            8 => Message::Shutdown,
            _ => Message::Ack,
        }
    }

    #[test]
    fn encode_decode_roundtrips_arbitrary_messages() {
        proptest::check(400, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            let got = Message::decode(&buf)
                .map_err(|e| format!("{} failed to decode: {e}",
                                     msg.kind()))?;
            crate::prop_assert!(got == msg,
                                "{} did not round-trip", msg.kind());
            Ok(())
        });
    }

    #[test]
    fn every_strict_prefix_is_rejected_without_panicking() {
        proptest::check(120, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            for cut in 0..buf.len() {
                crate::prop_assert!(
                    Message::decode(&buf[..cut]).is_err(),
                    "{} truncated to {cut}/{} bytes decoded",
                    msg.kind(),
                    buf.len()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        proptest::check(80, |rng| {
            let msg = arb_msg(rng);
            let mut buf = Vec::new();
            msg.encode(&mut buf);
            buf.push(0);
            crate::prop_assert!(Message::decode(&buf).is_err(),
                                "{} accepted a trailing byte", msg.kind());
            Ok(())
        });
    }

    #[test]
    fn client_encoders_match_owned_message_encoding() {
        // one byte layout: the borrowed hot-path encoders and
        // Message::encode must agree (they delegate, this pins it)
        let query = vec![1.5f32, -2.0, 0.25];
        let rows = vec![7u32, 3];
        let coords = vec![0u32, 2, 2];
        let mut a = Vec::new();
        encode_partial_sums(&mut a, Metric::L1, &query, &rows, &coords);
        let mut b = Vec::new();
        Message::PartialSums {
            metric: Metric::L1,
            query: query.clone(),
            rows: rows.clone(),
            coord_ids: coords.clone(),
        }
        .encode(&mut b);
        assert_eq!(a, b);
        let req = PullRequest { query: &query, rows: &rows,
                                coord_ids: &coords };
        encode_pull_batch(&mut a, Metric::L2Sq, &[req]);
        Message::PullBatch {
            metric: Metric::L2Sq,
            reqs: vec![WireRequest { query, rows, coord_ids: coords }],
        }
        .encode(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn bad_opcode_and_bad_metric_are_rejected() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[99]).is_err());
        // PartialSums with metric code 7
        assert!(Message::decode(&[3, 7, 0, 0, 0, 0]).is_err());
    }

    #[test]
    fn frames_roundtrip_and_oversized_headers_are_rejected() {
        let payload = vec![1u8, 2, 3, 4, 5];
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &payload).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        let mut got = Vec::new();
        read_frame(&mut cur, &mut got).unwrap();
        assert_eq!(got, payload);
        // forged header claiming a 2 GiB payload: rejected, no allocation
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let mut cur = std::io::Cursor::new(huge);
        let err = read_frame(&mut cur, &mut got).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // truncated stream: header promises more than arrives
        let mut short = 10u32.to_le_bytes().to_vec();
        short.extend_from_slice(&[1, 2, 3]);
        let mut cur = std::io::Cursor::new(short);
        assert!(read_frame(&mut cur, &mut got).is_err());
    }

    #[test]
    fn float_bits_survive_the_wire_exactly() {
        // bitwise parity across the network hinges on this: encode odd
        // bit patterns (negative zero, subnormals, inf) and compare bits
        let vals = vec![-0.0f64, f64::INFINITY, 1e-310, -3.5];
        let mut buf = Vec::new();
        encode_dists(&mut buf, &vals);
        match Message::decode(&buf).unwrap() {
            Message::Dists { vals: got } => {
                for (a, b) in vals.iter().zip(&got) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }
}
