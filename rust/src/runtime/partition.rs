//! Shared wave partitioning: the **one** splitter every sharded execution
//! substrate uses to fan engine waves across contiguous dataset-row
//! shards and scatter results back.
//!
//! Both the multi-core [`crate::runtime::sharded::ShardedEngine`] and the
//! networked [`crate::runtime::remote::RemoteEngine`] plan their waves
//! through [`WavePartition`], so a wave is split identically whether a
//! shard is a worker thread or a TCP endpoint. That is what makes the two
//! substrates provably interchangeable: `tests/sharded_parity.rs` and
//! `tests/remote_parity.rs` pin the same bitwise contract against the
//! same plan.
//!
//! See `docs/ARCHITECTURE.md` for how the partition slots into the wave
//! lifecycle.
//!
//! The partition itself is the contiguous floor-boundary split: shard `s`
//! of `S` owns rows `[floor(s·n/S), floor((s+1)·n/S))`. Splitting only
//! routes each (row, request) job to its owner and remembers the caller's
//! output slot; merging only *places* per-shard results back into those
//! slots. No arithmetic is reordered, which is why sharded output is
//! bitwise identical to single-threaded output for engines that compute
//! each job independently (every engine in this repo does).

#![deny(missing_docs)]

use crate::coordinator::arms::PullRequest;

/// Row range `[start, end)` owned by `shard` under the contiguous
/// floor-boundary partition of `n_rows` rows into `n_shards` shards.
#[inline]
pub fn shard_range(shard: usize, n_rows: usize, n_shards: usize)
                   -> (usize, usize) {
    debug_assert!(shard < n_shards);
    (shard * n_rows / n_shards, (shard + 1) * n_rows / n_shards)
}

/// Shard owning dataset row `row`: the unique `s` with
/// `shard_range(s, n, S).0 <= row < shard_range(s, n, S).1`.
#[inline]
pub fn shard_of(row: usize, n_rows: usize, n_shards: usize) -> usize {
    debug_assert!(row < n_rows);
    (((row + 1) * n_shards).saturating_sub(1) / n_rows).min(n_shards - 1)
}

/// One shard's slice of the current wave: which rows it computes, where
/// each result lands in the caller's request-major output layout, and —
/// for `pull_batch` waves — how its rows group back into sub-requests.
#[derive(Default)]
pub struct ShardWave {
    /// row ids of this shard's jobs, wave order (pull_batch: grouped by
    /// request, in the caller's request order)
    pub rows: Vec<u32>,
    /// caller-layout output slot per entry of `rows`
    pub slots: Vec<u32>,
    /// (request index, start, len) ranges into `rows` — pull_batch only
    pub req_ranges: Vec<(u32, u32, u32)>,
}

impl ShardWave {
    fn clear(&mut self) {
        self.rows.clear();
        self.slots.clear();
        self.req_ranges.clear();
    }

    /// Place this shard's per-job results (aligned with `rows`) back into
    /// the caller's output layout.
    pub fn scatter(&self, vals: &[f64], out: &mut [f64]) {
        debug_assert_eq!(vals.len(), self.slots.len());
        for (&slot, &v) in self.slots.iter().zip(vals) {
            out[slot as usize] = v;
        }
    }

    /// Rebuild this shard's sub-requests of a batch wave: each original
    /// request restricted to the rows this shard owns (possibly empty
    /// sub-requests are omitted — `req_ranges` only stores non-empty
    /// ranges). The sub-requests cover `rows` contiguously in order, so
    /// an engine's request-major concatenated output aligns with `slots`.
    pub fn subrequests<'a>(
        &'a self,
        reqs: &'a [PullRequest<'a>],
    ) -> impl Iterator<Item = PullRequest<'a>> + 'a {
        self.req_ranges.iter().map(move |&(ri, start, len)| {
            let r = &reqs[ri as usize];
            PullRequest {
                query: r.query,
                rows: &self.rows[start as usize..(start + len) as usize],
                coord_ids: r.coord_ids,
            }
        })
    }
}

/// Reusable per-engine wave planner: split a wave by row ownership, hand
/// each shard its [`ShardWave`], scatter results back. Buffers are
/// retained across waves so steady-state planning allocates nothing.
pub struct WavePartition {
    waves: Vec<ShardWave>,
}

impl WavePartition {
    /// A planner for `n_shards` contiguous row shards (must be > 0).
    pub fn new(n_shards: usize) -> WavePartition {
        assert!(n_shards > 0, "need at least one shard");
        WavePartition {
            waves: (0..n_shards).map(|_| ShardWave::default()).collect(),
        }
    }

    /// Number of shards this planner splits waves across.
    pub fn n_shards(&self) -> usize {
        self.waves.len()
    }

    /// Shard `shard`'s slice of the most recently split wave.
    pub fn wave(&self, shard: usize) -> &ShardWave {
        &self.waves[shard]
    }

    fn clear(&mut self) {
        for w in &mut self.waves {
            w.clear();
        }
    }

    /// Plan a single-query wave (`partial_sums` / `exact_dists`): route
    /// each of `rows` to its owning shard, remembering the caller index.
    pub fn split_rows(&mut self, n_rows: usize, rows: &[u32]) {
        self.clear();
        let s = self.waves.len();
        for (i, &r) in rows.iter().enumerate() {
            let w = &mut self.waves[shard_of(r as usize, n_rows, s)];
            w.rows.push(r);
            w.slots.push(i as u32);
        }
    }

    /// Plan a multi-request `pull_batch` wave request-major: each
    /// request's row list is split by ownership, every shard sees its
    /// sub-requests in the caller's request order, and slots index the
    /// concatenated request-major output. Returns the total job count.
    pub fn split_batch(&mut self, n_rows: usize, reqs: &[PullRequest<'_>])
                       -> usize {
        self.clear();
        let s = self.waves.len();
        let mut starts = vec![0u32; s];
        let mut slot = 0u32;
        for (ri, r) in reqs.iter().enumerate() {
            for (o, start) in starts.iter_mut().enumerate() {
                *start = self.waves[o].rows.len() as u32;
            }
            for &row in r.rows {
                let w = &mut self.waves[shard_of(row as usize, n_rows, s)];
                w.rows.push(row);
                w.slots.push(slot);
                slot += 1;
            }
            for (o, &start) in starts.iter().enumerate() {
                let w = &mut self.waves[o];
                let len = w.rows.len() as u32 - start;
                if len > 0 {
                    w.req_ranges.push((ri as u32, start, len));
                }
            }
        }
        slot as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_partition_is_contiguous_and_complete() {
        for n in [1usize, 2, 3, 5, 8, 16, 33] {
            for s in 1..=8usize {
                let owners: Vec<usize> =
                    (0..n).map(|r| shard_of(r, n, s)).collect();
                // monotone non-decreasing, within range, and matching the
                // floor-boundary sizes (zero-row shards allowed)
                for w in owners.windows(2) {
                    assert!(w[0] <= w[1]);
                }
                for (r, &o) in owners.iter().enumerate() {
                    assert!(o < s, "row {r} of {n} -> shard {o} >= {s}");
                    let (a, b) = shard_range(o, n, s);
                    assert!(r >= a && r < b,
                            "row {r} outside shard {o}'s range (n={n} s={s})");
                }
            }
        }
    }

    #[test]
    fn shard_ranges_tile_the_rows() {
        for n in [0usize, 1, 4, 7, 33] {
            for s in 1..=8usize {
                let mut next = 0usize;
                for o in 0..s {
                    let (a, b) = shard_range(o, n, s);
                    assert_eq!(a, next, "gap before shard {o} (n={n} s={s})");
                    assert!(b >= a);
                    next = b;
                }
                assert_eq!(next, n, "ranges must cover all rows");
            }
        }
    }

    #[test]
    fn split_rows_scatter_roundtrips() {
        // scatter(row id as payload) must reconstruct the caller's layout
        let rows: Vec<u32> = vec![6, 0, 6, 3, 5, 1, 2, 4, 0];
        let n = 7usize;
        for s in 1..=8usize {
            let mut part = WavePartition::new(s);
            part.split_rows(n, &rows);
            let mut out = vec![-1.0f64; rows.len()];
            let mut total = 0usize;
            for o in 0..s {
                let w = part.wave(o);
                assert_eq!(w.rows.len(), w.slots.len());
                total += w.rows.len();
                let vals: Vec<f64> =
                    w.rows.iter().map(|&r| r as f64).collect();
                w.scatter(&vals, &mut out);
            }
            assert_eq!(total, rows.len(), "every job routed exactly once");
            let want: Vec<f64> = rows.iter().map(|&r| r as f64).collect();
            assert_eq!(out, want, "s={s}");
        }
    }

    #[test]
    fn split_batch_slots_are_a_permutation_and_subrequests_align() {
        let queries: Vec<Vec<f32>> =
            (0..3).map(|i| vec![i as f32; 4]).collect();
        let rowsets: Vec<Vec<u32>> =
            vec![vec![0, 4, 2, 4], vec![], vec![3, 1, 0]];
        let coords: Vec<u32> = vec![0, 2];
        let reqs: Vec<PullRequest> = (0..3)
            .map(|i| PullRequest {
                query: &queries[i],
                rows: &rowsets[i],
                coord_ids: &coords,
            })
            .collect();
        let n = 5usize;
        for s in 1..=6usize {
            let mut part = WavePartition::new(s);
            let total = part.split_batch(n, &reqs);
            assert_eq!(total, 7);
            let mut seen = vec![false; total];
            for o in 0..s {
                let w = part.wave(o);
                for &slot in &w.slots {
                    assert!(!seen[slot as usize], "slot {slot} routed twice");
                    seen[slot as usize] = true;
                }
                // sub-requests tile this shard's rows in order
                let mut covered = 0usize;
                for sub in w.subrequests(&reqs) {
                    assert!(!sub.rows.is_empty());
                    assert_eq!(sub.rows.as_ptr(),
                               w.rows[covered..].as_ptr());
                    covered += sub.rows.len();
                }
                assert_eq!(covered, w.rows.len());
            }
            assert!(seen.iter().all(|&b| b), "every slot filled (s={s})");
        }
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_rejected() {
        let _ = WavePartition::new(0);
    }
}
