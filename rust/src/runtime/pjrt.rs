//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client, and
//! exposes them to the coordinator — including as a [`PullEngine`] so the
//! bandit hot loop can run its batched pulls through the compiled
//! JAX/Pallas kernels with a device-resident dataset.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos — see /opt/xla-example/README.md). Datasets are
//! uploaded once per `prepare()` via `buffer_from_host_buffer` and reused
//! across every round through `execute_b`; per round only the arm-id /
//! coord-id index vectors cross the host boundary.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::arms::PullEngine;
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::artifacts::Manifest;

/// Compiled-artifact cache over one PJRT client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client, manifest, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(&mut self, name: &str)
                      -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let spec = self.manifest.get(name).map_err(|e| anyhow!(e))?;
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().unwrap(),
            )
            .map_err(|e| anyhow!("parsing HLO text {:?}: {e:?}", spec.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Upload a host f32 buffer as a device-resident PJRT buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 buffer: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize])
                      -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 buffer: {e:?}"))
    }
}

/// [`PullEngine`] backed by the `pull_data_{metric}` artifacts with a
/// device-resident padded dataset.
///
/// Shape discipline: the artifact fixes (N, D, B, T) at AOT time. The
/// engine pads the dataset to N×D at `prepare` (zero columns/rows — both
/// ℓ1 and ℓ2² are padding-invariant since query padding is also zero),
/// splits pull batches into chunks of B (padding arm-ids by repeating arm
/// 0 and discarding those outputs), and requires `coord_ids.len() == T`
/// per chunk — the coordinator's `round_pulls` is aligned to T. When a
/// round's t < T (an arm near its MAX_PULLS cap), the coordinator falls
/// back to per-arm scalar pulls, so this engine never sees ragged t.
pub struct PjrtEngine {
    rt: PjrtRuntime,
    /// artifact params
    n_art: usize,
    d_art: usize,
    b_art: usize,
    t_art: usize,
    metric: Metric,
    /// device-resident padded dataset + its host fingerprint
    data_buf: Option<xla::PjRtBuffer>,
    data_fingerprint: u64,
    data_n: usize,
    data_d: usize,
    /// cached query upload (queries repeat across thousands of rounds)
    query_buf: Option<xla::PjRtBuffer>,
    query_cache: Vec<f32>,
    /// host→device scratch
    arm_scratch: Vec<i32>,
    coord_scratch: Vec<i32>,
    /// telemetry
    pub executions: u64,
}

fn fingerprint(data: &DenseDataset) -> u64 {
    // cheap structural fingerprint: dims + a few strided samples
    let raw = data.raw();
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    mix(data.n as u64);
    mix(data.d as u64);
    let step = (raw.len() / 64).max(1);
    for i in (0..raw.len()).step_by(step) {
        mix(raw[i].to_bits() as u64);
    }
    h
}

impl PjrtEngine {
    /// Build for a metric using the default artifact bundle.
    pub fn new(artifact_dir: &Path, metric: Metric) -> Result<Self> {
        let mut rt = PjrtRuntime::new(artifact_dir)?;
        let name = format!("pull_data_{}", metric.name());
        let spec = rt.manifest.get(&name).map_err(|e| anyhow!(e))?.clone();
        let n_art = spec.meta_usize("n")
            .ok_or_else(|| anyhow!("artifact {name} missing meta n"))?;
        let d_art = spec.meta_usize("d")
            .ok_or_else(|| anyhow!("artifact {name} missing meta d"))?;
        let b_art = spec.meta_usize("b")
            .ok_or_else(|| anyhow!("artifact {name} missing meta b"))?;
        let t_art = spec.meta_usize("t")
            .ok_or_else(|| anyhow!("artifact {name} missing meta t"))?;
        // warm the compile cache up front
        rt.executable(&name)?;
        Ok(PjrtEngine {
            rt,
            n_art,
            d_art,
            b_art,
            t_art,
            metric,
            data_buf: None,
            data_fingerprint: 0,
            data_n: 0,
            data_d: 0,
            query_buf: None,
            query_cache: Vec::new(),
            arm_scratch: Vec::new(),
            coord_scratch: Vec::new(),
            executions: 0,
        })
    }

    pub fn round_pulls(&self) -> u64 {
        self.t_art as u64
    }

    pub fn batch_arms(&self) -> usize {
        self.b_art
    }

    /// Upload (pad) the dataset once; subsequent calls with the same data
    /// are no-ops.
    pub fn prepare(&mut self, data: &DenseDataset) -> Result<()> {
        let fp = fingerprint(data);
        if self.data_buf.is_some() && fp == self.data_fingerprint {
            return Ok(());
        }
        if data.n > self.n_art {
            bail!("dataset n={} exceeds artifact N={} — rebuild artifacts \
                   with a larger N (python -m compile.aot)",
                  data.n, self.n_art);
        }
        if data.d > self.d_art {
            bail!("dataset d={} exceeds artifact D={}", data.d, self.d_art);
        }
        // pad rows and dims with zeros
        let mut padded = vec![0f32; self.n_art * self.d_art];
        for i in 0..data.n {
            padded[i * self.d_art..i * self.d_art + data.d]
                .copy_from_slice(data.row(i));
        }
        self.data_buf =
            Some(self.rt.upload_f32(&padded, &[self.n_art, self.d_art])?);
        self.data_fingerprint = fp;
        self.data_n = data.n;
        self.data_d = data.d;
        self.query_buf = None;
        self.query_cache.clear();
        Ok(())
    }

    fn ensure_query(&mut self, query: &[f32]) -> Result<()> {
        if self.query_buf.is_some() && self.query_cache == query {
            return Ok(());
        }
        let mut padded = vec![0f32; self.d_art];
        padded[..query.len()].copy_from_slice(query);
        self.query_buf = Some(self.rt.upload_f32(&padded, &[self.d_art])?);
        self.query_cache = query.to_vec();
        Ok(())
    }

    /// One artifact execution over ≤ B arms with exactly T coords.
    fn exec_chunk(&mut self, rows: &[u32], coord_ids: &[u32],
                  out_sum: &mut Vec<f64>, out_sq: &mut Vec<f64>)
                  -> Result<()> {
        debug_assert_eq!(coord_ids.len(), self.t_art);
        debug_assert!(rows.len() <= self.b_art);
        self.arm_scratch.clear();
        self.arm_scratch
            .extend(rows.iter().map(|&r| r as i32));
        // pad with arm 0 (outputs discarded)
        self.arm_scratch.resize(self.b_art, 0);
        self.coord_scratch.clear();
        self.coord_scratch
            .extend(coord_ids.iter().map(|&c| c as i32));
        let arm_buf =
            self.rt.upload_i32(&self.arm_scratch, &[self.b_art])?;
        let coord_buf =
            self.rt.upload_i32(&self.coord_scratch, &[self.t_art])?;
        let name = format!("pull_data_{}", self.metric.name());
        let data_buf = self.data_buf.as_ref().unwrap();
        let query_buf = self.query_buf.as_ref().unwrap();
        // keep borrows alive across the executable() mutable borrow
        let args: Vec<&xla::PjRtBuffer> =
            vec![data_buf, query_buf, &arm_buf, &coord_buf];
        let exe = {
            // executable() needs &mut self.rt; split the borrow by taking
            // the compiled entry pointer first
            let rt = &mut self.rt;
            rt.executable(&name)? as *const xla::PjRtLoadedExecutable
        };
        // SAFETY: `compiled` entries are never evicted, and `execute_b`
        // takes &self; the raw pointer outlives only this call.
        let exe = unsafe { &*exe };
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        self.executions += 1;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e:?}"))?;
        let (sums, sqs) = lit
            .to_tuple2()
            .map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let sums: Vec<f32> =
            sums.to_vec().map_err(|e| anyhow!("sum vec: {e:?}"))?;
        let sqs: Vec<f32> =
            sqs.to_vec().map_err(|e| anyhow!("sq vec: {e:?}"))?;
        for i in 0..rows.len() {
            out_sum.push(sums[i] as f64);
            out_sq.push(sqs[i] as f64);
        }
        Ok(())
    }
}

impl PullEngine for PjrtEngine {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        assert_eq!(metric, self.metric, "engine compiled for {:?}",
                   self.metric);
        out_sum.clear();
        out_sq.clear();
        // ragged t or oversized datasets fall back to scalar loops —
        // correctness first, and the coordinator aligns t to T on the
        // hot path anyway.
        if coord_ids.len() != self.t_art || data.n > self.n_art
            || data.d > self.d_art
        {
            let mut scalar = crate::coordinator::arms::ScalarEngine;
            scalar.partial_sums(data, query, rows, coord_ids, metric,
                                out_sum, out_sq);
            return;
        }
        self.prepare(data).expect("pjrt prepare");
        self.ensure_query(query).expect("pjrt query upload");
        for chunk in rows.chunks(self.b_art) {
            self.exec_chunk(chunk, coord_ids, out_sum, out_sq)
                .expect("pjrt execute");
        }
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        // exact path: native loops (the exact_rows artifact exists and is
        // exercised by the parity tests; the engine keeps exact on the
        // host because it is called for at most a handful of arms per
        // query)
        let mut scalar = crate::coordinator::arms::ScalarEngine;
        scalar.exact_dists(data, query, rows, metric, out);
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Standalone check: run `exact_rows_{metric}` through PJRT and compare to
/// host computation. Used by integration tests and `bmonn selftest`.
pub fn verify_exact_artifact(rt: &mut PjrtRuntime, metric: Metric)
                             -> Result<f64> {
    let name = format!("exact_rows_{}", metric.name());
    let spec = rt.manifest.get(&name).map_err(|e| anyhow!(e))?.clone();
    let b = spec.meta_usize("b").context("meta b")?;
    let d = spec.meta_usize("d").context("meta d")?;
    let mut rng = crate::util::rng::Rng::new(0xE7AC7);
    let rows: Vec<f32> = (0..b * d).map(|_| rng.gaussian() as f32).collect();
    let query: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    let rows_buf = rt.upload_f32(&rows, &[b, d])?;
    let query_buf = rt.upload_f32(&query, &[d])?;
    let exe = rt.executable(&name)?;
    let result = exe
        .execute_b(&[&rows_buf, &query_buf])
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("readback: {e:?}"))?;
    let got: Vec<f32> = lit
        .to_tuple1()
        .map_err(|e| anyhow!("tuple1: {e:?}"))?
        .to_vec()
        .map_err(|e| anyhow!("vec: {e:?}"))?;
    let mut max_rel = 0f64;
    for i in 0..b {
        let want = crate::data::dense::dist_slices(
            &rows[i * d..(i + 1) * d], &query, metric);
        let rel = ((got[i] as f64 - want) / want.max(1e-9)).abs();
        if rel > max_rel {
            max_rel = rel;
        }
    }
    Ok(max_rel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::ScalarEngine;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn artifacts_available() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn pjrt_engine_matches_scalar() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut engine =
            PjrtEngine::new(&Manifest::default_dir(), Metric::L2Sq).unwrap();
        let ds = synthetic::image_like(100, 512, 201);
        let query = ds.row_vec(0);
        let mut rng = Rng::new(202);
        let rows: Vec<u32> = (1..65).collect();
        let coords: Vec<u32> = (0..engine.round_pulls())
            .map(|_| rng.below(512) as u32)
            .collect();
        let (mut s_p, mut q_p) = (Vec::new(), Vec::new());
        engine.partial_sums(&ds, &query, &rows, &coords, Metric::L2Sq,
                            &mut s_p, &mut q_p);
        let mut scalar = ScalarEngine;
        let (mut s_s, mut q_s) = (Vec::new(), Vec::new());
        scalar.partial_sums(&ds, &query, &rows, &coords, Metric::L2Sq,
                            &mut s_s, &mut q_s);
        assert_eq!(s_p.len(), s_s.len());
        for i in 0..s_p.len() {
            assert!((s_p[i] - s_s[i]).abs() < 1e-2 * s_s[i].abs().max(1.0),
                    "sum {i}: pjrt {} scalar {}", s_p[i], s_s[i]);
            assert!((q_p[i] - q_s[i]).abs() < 1e-2 * q_s[i].abs().max(1.0),
                    "sq {i}: pjrt {} scalar {}", q_p[i], q_s[i]);
        }
        assert!(engine.executions >= 1);
    }

    #[test]
    fn exact_artifact_verifies() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = PjrtRuntime::new(&Manifest::default_dir()).unwrap();
        for metric in [Metric::L2Sq, Metric::L1] {
            let rel = verify_exact_artifact(&mut rt, metric).unwrap();
            assert!(rel < 1e-3, "{metric:?} max rel err {rel}");
        }
    }

    #[test]
    fn ragged_t_falls_back_to_scalar() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut engine =
            PjrtEngine::new(&Manifest::default_dir(), Metric::L2Sq).unwrap();
        let ds = synthetic::gaussian_iid(10, 64, 203);
        let query = ds.row_vec(0);
        let coords = [1u32, 5, 7]; // t=3 != T
        let (mut s, mut q) = (Vec::new(), Vec::new());
        engine.partial_sums(&ds, &query, &[1, 2], &coords, Metric::L2Sq,
                            &mut s, &mut q);
        assert_eq!(s.len(), 2);
        assert_eq!(engine.executions, 0, "should not have hit pjrt");
    }
}
