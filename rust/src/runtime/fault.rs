//! Deterministic fault injection for the shard ring: a seeded,
//! scripted TCP proxy that sits between a [`RingClient`] and a
//! [`ShardServer`] and misbehaves on schedule.
//!
//! The failover/blacklist/degraded machinery (PRs 4–5) is the repo's
//! robustness crown jewel, but hand-written kill scenarios only cover
//! the faults someone thought of. A [`FaultProxy`] makes the network
//! itself scriptable: point a ring client at `proxy.endpoint()` instead
//! of the real shard server and the proxy forwards the wave-tagged
//! frames while injecting the faults of its [`FaultPlan`] —
//!
//! * **delay** — hold a specific frame for a fixed or seeded-random
//!   number of milliseconds before forwarding it (slow replica, GC
//!   pause, cross-AZ hiccup);
//! * **drop mid-frame** — forward the length header plus *half* the
//!   payload, then sever both sides (process death at the worst
//!   possible byte);
//! * **corrupt** — flip a bit in the frame's opcode/tag region so the
//!   receiver sees *detectably* bad bytes (the wire protocol carries
//!   no payload checksum, so corrupting numeric payload bytes would be
//!   silent — the proxy deliberately corrupts where the decoder or the
//!   demux router must notice);
//! * **blackhole** — accept connections, swallow every frame, answer
//!   nothing (a live TCP endpoint whose process is wedged — the
//!   failure mode only I/O timeouts can detect);
//! * **partition until epoch** — blackhole until
//!   [`FaultProxy::advance_epoch`] reaches a threshold, then heal (a
//!   network partition with a scriptable end).
//!
//! Every random choice draws from one seeded [`Rng`], so a fault
//! schedule replays exactly given the same seed and frame order.
//! `tests/chaos.rs` drives seeded schedules over replicated rings and
//! asserts the standing invariant: zero query errors and
//! bitwise-identical answers while any replica of each shard survives;
//! clean structured errors — never hangs — otherwise.
//!
//! [`RingClient`]: crate::runtime::remote::RingClient
//! [`ShardServer`]: crate::runtime::remote::ShardServer

#![deny(missing_docs)]

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::runtime::wire;
use crate::util::rng::Rng;

/// Which direction of the proxied byte stream a rule applies to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// client → server frames (requests)
    ToServer,
    /// server → client frames (replies)
    ToClient,
}

fn dir_index(dir: Dir) -> usize {
    match dir {
        Dir::ToServer => 0,
        Dir::ToClient => 1,
    }
}

/// One scripted misbehavior, applied when its [`FaultRule`] matches.
#[derive(Clone, Copy, Debug)]
pub enum FaultAction {
    /// hold the frame for exactly this many milliseconds, then forward
    Delay(u64),
    /// hold the frame for a seeded-uniform duration in `[lo, hi]` ms
    DelayRange(u64, u64),
    /// forward the length header and half the payload, then sever the
    /// connection — the receiver sees a truncated frame and EOF
    DropMidFrame,
    /// flip a bit in the opcode/tag region before forwarding, so the
    /// receiver's decoder or demux router must reject the frame
    Corrupt,
}

/// Bind a [`FaultAction`] to one frame of one direction. Frames are
/// counted per direction from 0 across the proxy's whole lifetime
/// (connections included), so a schedule survives reconnects.
#[derive(Clone, Copy, Debug)]
pub struct FaultRule {
    /// direction the rule watches
    pub dir: Dir,
    /// per-direction frame index the rule fires on
    pub frame: u64,
    /// what to do to that frame
    pub action: FaultAction,
}

/// A complete seeded fault schedule for one proxy. The default plan
/// (seed 0, no rules, no blackhole, no partition) is a transparent
/// proxy.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// seed for every random choice the schedule makes
    pub seed: u64,
    /// per-frame scripted actions
    pub rules: Vec<FaultRule>,
    /// start blackholed: accept, swallow, never answer (toggle at
    /// runtime with [`FaultProxy::set_blackhole`])
    pub blackhole: bool,
    /// behave blackholed while `epoch() < this`; healing is scripted
    /// by [`FaultProxy::advance_epoch`]
    pub partition_until_epoch: Option<u64>,
}

struct ProxyShared {
    upstream: String,
    rules: Vec<FaultRule>,
    blackhole: AtomicBool,
    epoch: AtomicU64,
    partition_until: Option<u64>,
    /// per-direction frame counters (proxy lifetime, all connections)
    frames: [AtomicU64; 2],
    shutdown: AtomicBool,
    rng: Mutex<Rng>,
    /// every live socket (client and upstream sides), killed on stop
    conns: Mutex<Vec<TcpStream>>,
    pumps: Mutex<Vec<JoinHandle<()>>>,
}

impl ProxyShared {
    /// Is the proxy currently swallowing traffic (blackhole or an
    /// unhealed partition)?
    fn severed(&self) -> bool {
        self.blackhole.load(Ordering::SeqCst)
            || self
                .partition_until
                .is_some_and(|e| self.epoch.load(Ordering::SeqCst) < e)
    }

    fn register(&self, s: &TcpStream) {
        if let Ok(c) = s.try_clone() {
            self.conns.lock().unwrap().push(c);
        }
    }
}

/// A running fault-injection proxy (see module docs). Stops on drop.
pub struct FaultProxy {
    /// bound address of the proxy's listener (hand
    /// [`FaultProxy::endpoint`] to the ring client as the shard's
    /// endpoint)
    pub addr: SocketAddr,
    shared: Arc<ProxyShared>,
    accept_handle: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Start a proxy on a loopback ephemeral port, forwarding to
    /// `upstream` (a shard server endpoint) under `plan`.
    pub fn start(upstream: &str, plan: FaultPlan)
                 -> io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(ProxyShared {
            upstream: upstream.to_string(),
            rules: plan.rules,
            blackhole: AtomicBool::new(plan.blackhole),
            epoch: AtomicU64::new(0),
            partition_until: plan.partition_until_epoch,
            frames: [AtomicU64::new(0), AtomicU64::new(0)],
            shutdown: AtomicBool::new(false),
            rng: Mutex::new(Rng::new(plan.seed)),
            conns: Mutex::new(Vec::new()),
            pumps: Mutex::new(Vec::new()),
        });
        let accept_shared = shared.clone();
        let accept_handle = std::thread::Builder::new()
            .name("bmonn-fault-proxy".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .expect("spawn fault-proxy accept thread");
        Ok(FaultProxy { addr, shared, accept_handle: Some(accept_handle) })
    }

    /// `host:port` string of the proxy's listener — what the ring
    /// client should dial instead of the real shard server.
    pub fn endpoint(&self) -> String {
        self.addr.to_string()
    }

    /// Toggle blackhole mode at runtime. Turning it on swallows every
    /// frame of existing connections too; turning it off lets new
    /// connections through (frames swallowed while severed are lost —
    /// the client's timeout/failover machinery is what recovers them).
    pub fn set_blackhole(&self, on: bool) {
        self.shared.blackhole.store(on, Ordering::SeqCst);
    }

    /// Current partition epoch (starts at 0).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::SeqCst)
    }

    /// Advance the partition epoch by one, returning the new value —
    /// once it reaches the plan's `partition_until_epoch`, the
    /// partition heals.
    pub fn advance_epoch(&self) -> u64 {
        self.shared.epoch.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Frames forwarded (or swallowed) so far in `dir`, across every
    /// connection of the proxy's lifetime.
    pub fn frames(&self, dir: Dir) -> u64 {
        self.shared.frames[dir_index(dir)].load(Ordering::SeqCst)
    }

    /// Stop proxying: sever every live connection (both sides see EOF,
    /// like a middlebox death) and join the worker threads.
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for s in self.shared.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.shared.pumps.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<ProxyShared>) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((client, _)) => {
                shared.register(&client);
                if shared.severed() {
                    // accept-then-silence: hold the socket open and
                    // swallow whatever arrives; no upstream is dialed,
                    // so healing requires the client to reconnect
                    // (exactly what failover does)
                    let sh = shared.clone();
                    let h = std::thread::spawn(move || {
                        swallow_conn(client, &sh);
                    });
                    shared.pumps.lock().unwrap().push(h);
                    continue;
                }
                let Ok(server) = TcpStream::connect(&shared.upstream)
                else {
                    // upstream down: sever the client (it sees EOF,
                    // the same signal a dead shard server produces)
                    let _ = client.shutdown(Shutdown::Both);
                    continue;
                };
                shared.register(&server);
                let (Ok(c2), Ok(s2)) =
                    (client.try_clone(), server.try_clone())
                else {
                    let _ = client.shutdown(Shutdown::Both);
                    let _ = server.shutdown(Shutdown::Both);
                    continue;
                };
                let sh_a = shared.clone();
                let sh_b = shared.clone();
                let mut pumps = shared.pumps.lock().unwrap();
                pumps.push(std::thread::spawn(move || {
                    pump(client, s2, Dir::ToServer, &sh_a);
                }));
                pumps.push(std::thread::spawn(move || {
                    pump(server, c2, Dir::ToClient, &sh_b);
                }));
                pumps.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                // test harness: favor low, predictable accept latency
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    for s in shared.conns.lock().unwrap().iter() {
        let _ = s.shutdown(Shutdown::Both);
    }
}

/// Hold a severed connection open, discarding whatever the client
/// writes (its sends succeed — nothing ever answers), until the client
/// hangs up or the proxy stops.
fn swallow_conn(mut client: TcpStream, shared: &ProxyShared) {
    let _ = client.set_read_timeout(Some(Duration::from_millis(50)));
    let mut sink = [0u8; 4096];
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match client.read(&mut sink) {
            Ok(0) => return,
            Ok(_) => {}
            Err(ref e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            Err(_) => return,
        }
    }
}

/// Forward one direction of a proxied connection frame by frame,
/// applying the schedule's matching rules. Exits (severing both sides)
/// on any I/O error or a `DropMidFrame` rule.
fn pump(mut src: TcpStream, mut dst: TcpStream, dir: Dir,
        shared: &ProxyShared) {
    let sever = |src: &TcpStream, dst: &TcpStream| {
        let _ = src.shutdown(Shutdown::Both);
        let _ = dst.shutdown(Shutdown::Both);
    };
    let mut header = [0u8; 4];
    let mut payload = Vec::new();
    loop {
        if src.read_exact(&mut header).is_err() {
            sever(&src, &dst);
            return;
        }
        let len = u32::from_le_bytes(header) as usize;
        if len > wire::MAX_FRAME {
            sever(&src, &dst);
            return;
        }
        payload.clear();
        payload.resize(len, 0);
        if src.read_exact(&mut payload).is_err() {
            sever(&src, &dst);
            return;
        }
        let idx = shared.frames[dir_index(dir)]
            .fetch_add(1, Ordering::SeqCst);
        let mut drop_mid_frame = false;
        for rule in shared.rules.iter() {
            if rule.dir != dir || rule.frame != idx {
                continue;
            }
            match rule.action {
                FaultAction::Delay(ms) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                FaultAction::DelayRange(lo, hi) => {
                    let (lo, hi) = (lo.min(hi), lo.max(hi));
                    let span = (hi - lo) as usize + 1;
                    let ms = lo
                        + shared.rng.lock().unwrap().below(span) as u64;
                    std::thread::sleep(Duration::from_millis(ms));
                }
                FaultAction::DropMidFrame => drop_mid_frame = true,
                FaultAction::Corrupt => {
                    // flip a bit where the receiver must notice: the
                    // opcode (decoder rejects the frame) or the top
                    // wave-tag byte (the demux router sees a reply for
                    // a wave that cannot be pending). Payload bytes are
                    // left alone — the protocol has no checksum, so
                    // that corruption would be *silent*, which is a
                    // protocol gap to test for, not a fault to inject.
                    if payload.is_empty() {
                        continue;
                    }
                    let flip_op =
                        shared.rng.lock().unwrap().below(2) == 0;
                    if flip_op || payload.len() < 9 {
                        payload[0] ^= 0xFF;
                    } else {
                        payload[8] ^= 0xFF;
                    }
                }
            }
        }
        if shared.severed() {
            // swallowed: the frame counter advanced, nothing forwards
            continue;
        }
        if drop_mid_frame {
            let half = &payload[..len / 2];
            let _ = dst.write_all(&header);
            let _ = dst.write_all(half);
            sever(&src, &dst);
            return;
        }
        if dst.write_all(&header).is_err()
            || dst.write_all(&payload).is_err()
        {
            sever(&src, &dst);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    /// A minimal frame echo server: reads a frame, writes the same
    /// payload back as a frame, until the peer hangs up.
    fn echo_server() -> (String, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            // serves connections one at a time until the test process
            // exits — plenty for these scenarios
            while let Ok((mut s, _)) = listener.accept() {
                let mut buf = Vec::new();
                loop {
                    if wire::read_frame(&mut s, &mut buf).is_err() {
                        break;
                    }
                    if wire::write_frame(&mut s, &buf).is_err() {
                        break;
                    }
                }
            }
        });
        (ep, h)
    }

    fn round_trip(s: &mut TcpStream, payload: &[u8]) -> Vec<u8> {
        wire::write_frame(s, payload).unwrap();
        let mut buf = Vec::new();
        wire::read_frame(s, &mut buf).unwrap();
        buf
    }

    #[test]
    fn clean_proxy_is_transparent_and_counts_frames() {
        let (ep, _h) = echo_server();
        let proxy =
            FaultProxy::start(&ep, FaultPlan::default()).unwrap();
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        let msg = vec![101u8, 1, 2, 3, 4, 5, 6, 7, 8, 9];
        assert_eq!(round_trip(&mut s, &msg), msg);
        assert_eq!(round_trip(&mut s, &msg), msg);
        assert_eq!(proxy.frames(Dir::ToServer), 2);
        assert_eq!(proxy.frames(Dir::ToClient), 2);
    }

    #[test]
    fn delay_rule_holds_exactly_the_matching_frame() {
        let (ep, _h) = echo_server();
        let plan = FaultPlan {
            rules: vec![FaultRule {
                dir: Dir::ToServer,
                frame: 1,
                action: FaultAction::Delay(80),
            }],
            ..FaultPlan::default()
        };
        let proxy = FaultProxy::start(&ep, plan).unwrap();
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        let msg = vec![9u8; 16];
        let t0 = Instant::now();
        round_trip(&mut s, &msg);
        let first = t0.elapsed();
        let t1 = Instant::now();
        round_trip(&mut s, &msg);
        let second = t1.elapsed();
        assert!(second >= Duration::from_millis(80),
                "delayed frame answered in {second:?}");
        assert!(first < Duration::from_millis(80),
                "undelayed frame took {first:?}");
    }

    #[test]
    fn drop_mid_frame_severs_with_a_truncated_frame() {
        let (ep, _h) = echo_server();
        let plan = FaultPlan {
            rules: vec![FaultRule {
                dir: Dir::ToClient,
                frame: 0,
                action: FaultAction::DropMidFrame,
            }],
            ..FaultPlan::default()
        };
        let proxy = FaultProxy::start(&ep, plan).unwrap();
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        wire::write_frame(&mut s, &[7u8; 32]).unwrap();
        let mut buf = Vec::new();
        assert!(wire::read_frame(&mut s, &mut buf).is_err(),
                "a mid-frame drop must not deliver a whole frame");
    }

    #[test]
    fn corruption_is_detectable_and_seed_deterministic() {
        let received = |seed: u64| {
            let (ep, _h) = echo_server();
            let plan = FaultPlan {
                seed,
                rules: vec![FaultRule {
                    dir: Dir::ToServer,
                    frame: 0,
                    action: FaultAction::Corrupt,
                }],
                ..FaultPlan::default()
            };
            let proxy = FaultProxy::start(&ep, plan).unwrap();
            let mut s = TcpStream::connect(proxy.addr).unwrap();
            round_trip(&mut s, &[42u8; 12])
        };
        let a = received(7);
        assert_ne!(a, vec![42u8; 12], "corruption must alter the frame");
        // only the opcode or the top tag byte may differ
        let diffs: Vec<usize> = (0..12)
            .filter(|&i| a[i] != 42)
            .collect();
        assert!(diffs == vec![0] || diffs == vec![8],
                "corruption outside the header region: {diffs:?}");
        assert_eq!(a, received(7), "same seed must corrupt identically");
    }

    #[test]
    fn blackhole_accepts_swallows_and_heals_on_clear() {
        let (ep, _h) = echo_server();
        let plan =
            FaultPlan { blackhole: true, ..FaultPlan::default() };
        let proxy = FaultProxy::start(&ep, plan).unwrap();
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        wire::write_frame(&mut s, &[1u8; 8]).unwrap();
        let mut buf = Vec::new();
        let err = wire::read_frame(&mut s, &mut buf).unwrap_err();
        assert!(matches!(err.kind(), io::ErrorKind::WouldBlock
                                     | io::ErrorKind::TimedOut),
                "a blackhole must time the reader out, not EOF: {err}");
        // healing: clear the blackhole, reconnect (what failover does)
        proxy.set_blackhole(false);
        let mut s2 = TcpStream::connect(proxy.addr).unwrap();
        assert_eq!(round_trip(&mut s2, &[2u8; 8]), vec![2u8; 8]);
    }

    #[test]
    fn partition_heals_when_the_epoch_arrives() {
        let (ep, _h) = echo_server();
        let plan = FaultPlan {
            partition_until_epoch: Some(1),
            ..FaultPlan::default()
        };
        let proxy = FaultProxy::start(&ep, plan).unwrap();
        let mut s = TcpStream::connect(proxy.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        wire::write_frame(&mut s, &[3u8; 8]).unwrap();
        let mut buf = Vec::new();
        assert!(wire::read_frame(&mut s, &mut buf).is_err(),
                "partitioned proxy must answer nothing");
        assert_eq!(proxy.advance_epoch(), 1);
        let mut s2 = TcpStream::connect(proxy.addr).unwrap();
        assert_eq!(round_trip(&mut s2, &[4u8; 8]), vec![4u8; 8]);
    }
}
