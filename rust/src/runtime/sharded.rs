//! Sharded multi-core pull execution: fan each engine wave out across
//! contiguous dataset-row shards, merge bit-identically.
//!
//! [`ShardedEngine<E>`] wraps any [`PullEngine`] and partitions dataset
//! rows into `S` contiguous shards, each owned by one worker of a
//! persistent [`ScopedPool`] (std threads only — the default build stays
//! dependency-free). Every `partial_sums` / `exact_dists` / `pull_batch`
//! wave is planned by the shared splitter in
//! [`crate::runtime::partition`] — the same [`WavePartition`] the
//! networked `runtime::remote::RemoteEngine` fans waves over TCP with —
//! executed per shard by a per-shard clone of the inner engine, and
//! scattered back into the caller's request-major output layout.
//!
//! **Determinism.** Every engine in this repo computes each (row, query,
//! coords) job independently of the other jobs in a wave — the unrolled
//! row kernels accumulate within a row only. A shard therefore runs the
//! exact same per-row float summation the single-threaded engine would,
//! and the merge only *places* results, so sharded output is bitwise
//! identical to `E` run single-threaded, for any shard count
//! (`tests/sharded_parity.rs` pins this for 1–8 shards, uneven splits,
//! zero-row shards and n < S).
//!
//! Small waves (a ragged single-arm pull, one exact evaluation) are run
//! inline on shard 0: the condvar dispatch round-trip costs more than
//! the arithmetic it would spread. The cutoff only moves work between
//! the inline and pooled paths — results are identical either way.

#![deny(missing_docs)]

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::arms::{PullEngine, PullRequest};
use crate::data::dense::{DenseDataset, Metric};
use crate::runtime::partition::WavePartition;

/// Waves below this many coordinate operations run inline on shard 0
/// instead of paying the pool dispatch round-trip (~tens of µs).
const MIN_PARALLEL_OPS: usize = 16384;

/// Lifetime-erased `&(dyn Fn(usize) + Sync)` handed to pool workers.
/// Safe to send because [`ScopedPool::run`] blocks until every worker
/// has finished calling it.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskPtr {}

struct PoolState {
    task: Option<TaskPtr>,
    /// bumped once per dispatched wave; workers run each generation once
    generation: u64,
    /// workers still executing the current generation
    remaining: usize,
    /// a worker's task panicked this wave (re-raised by `run`, so the
    /// dispatcher fails loudly instead of hanging on `remaining`)
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// A persistent pool of workers executing *borrowed* closures: `run`
/// publishes a `&dyn Fn(worker_index)`, wakes every worker, and blocks
/// until all have finished — so the task may borrow from the caller's
/// stack ("scoped" dispatch without re-spawning threads per wave).
pub struct ScopedPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ScopedPool {
    /// Spawn a pool of `n_workers` persistent worker threads (> 0).
    pub fn new(n_workers: usize) -> ScopedPool {
        assert!(n_workers > 0);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                task: None,
                generation: 0,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..n_workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("bmonn-shard-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawn shard worker")
            })
            .collect();
        ScopedPool { shared, workers }
    }

    /// Number of worker threads in the pool.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `task(i)` for every worker index `i`, returning once all have
    /// finished (which is what makes the borrow in `task` sound).
    pub fn run(&mut self, task: &(dyn Fn(usize) + Sync)) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.task = Some(TaskPtr(task as *const _));
            st.generation += 1;
            st.remaining = self.workers.len();
        }
        self.shared.work_cv.notify_all();
        let mut st = self.shared.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.task = None;
        if st.panicked {
            st.panicked = false;
            drop(st);
            panic!("sharded pull worker panicked");
        }
    }
}

impl Drop for ScopedPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>, idx: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen {
                    if let Some(t) = st.task {
                        seen = st.generation;
                        break t;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: `run` holds its caller (and thus the referent of the
        // erased borrow) blocked until `remaining` hits 0, which happens
        // strictly after this call returns.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*task.0 };
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(idx)
            }));
        let mut st = shared.state.lock().unwrap();
        if outcome.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// One shard's engine plus its output scratch. Workers touch only their
/// own entry (behind an uncontended Mutex); the wave plan itself lives
/// in the engine-level [`WavePartition`], shared read-only.
struct ShardState<E> {
    engine: E,
    out_sum: Vec<f64>,
    out_sq: Vec<f64>,
}

/// Sharded parallel wrapper around any [`PullEngine`] — see the module
/// docs for the determinism contract. Construct via
/// [`ShardedEngine::new`] or the [`crate::runtime::build_host_engine`]
/// factory (`[engine] shards` / `--shards`).
pub struct ShardedEngine<E> {
    shards: Vec<Mutex<ShardState<E>>>,
    partition: WavePartition,
    /// present only when there is more than one shard
    pool: Option<ScopedPool>,
}

impl<E: PullEngine + Clone + Send> ShardedEngine<E> {
    /// `n_shards` is clamped to at least 1; each shard gets a clone of
    /// `engine` (engines carry only scratch state, so clones are cheap).
    pub fn new(engine: E, n_shards: usize) -> ShardedEngine<E> {
        let s = n_shards.max(1);
        let shards = (0..s)
            .map(|_| {
                Mutex::new(ShardState {
                    engine: engine.clone(),
                    out_sum: Vec::new(),
                    out_sq: Vec::new(),
                })
            })
            .collect();
        let pool = if s > 1 { Some(ScopedPool::new(s)) } else { None };
        ShardedEngine { shards, partition: WavePartition::new(s), pool }
    }

    /// Number of row shards (= pool workers) waves fan out across.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

impl<E: PullEngine + Clone + Send> PullEngine for ShardedEngine<E> {
    fn partial_sums(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        coord_ids: &[u32],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let s = self.shards.len();
        let work = rows.len() * coord_ids.len().max(1);
        if s == 1 || work < MIN_PARALLEL_OPS {
            let st = self.shards[0].get_mut().unwrap();
            st.engine.partial_sums(data, query, rows, coord_ids, metric,
                                   out_sum, out_sq);
            return;
        }
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(rows.len(), 0.0);
        out_sq.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let part = &self.partition;
        let shards = &self.shards;
        self.pool.as_mut().unwrap().run(&|i: usize| {
            let mut guard = shards[i].lock().unwrap();
            let st = &mut *guard;
            let wave = part.wave(i);
            st.engine.partial_sums(data, query, &wave.rows, coord_ids,
                                   metric, &mut st.out_sum,
                                   &mut st.out_sq);
        });
        for (i, sh) in self.shards.iter_mut().enumerate() {
            let st = sh.get_mut().unwrap();
            let wave = self.partition.wave(i);
            wave.scatter(&st.out_sum, out_sum);
            wave.scatter(&st.out_sq, out_sq);
        }
    }

    fn exact_dists(
        &mut self,
        data: &DenseDataset,
        query: &[f32],
        rows: &[u32],
        metric: Metric,
        out: &mut Vec<f64>,
    ) {
        let s = self.shards.len();
        let work = rows.len() * data.d.max(1);
        if s == 1 || work < MIN_PARALLEL_OPS {
            let st = self.shards[0].get_mut().unwrap();
            st.engine.exact_dists(data, query, rows, metric, out);
            return;
        }
        out.clear();
        out.resize(rows.len(), 0.0);
        self.partition.split_rows(data.n, rows);
        let part = &self.partition;
        let shards = &self.shards;
        self.pool.as_mut().unwrap().run(&|i: usize| {
            let mut guard = shards[i].lock().unwrap();
            let st = &mut *guard;
            let wave = part.wave(i);
            st.engine.exact_dists(data, query, &wave.rows, metric,
                                  &mut st.out_sum);
        });
        for (i, sh) in self.shards.iter_mut().enumerate() {
            let st = sh.get_mut().unwrap();
            self.partition.wave(i).scatter(&st.out_sum, out);
        }
    }

    /// The multi-query wave: split every request's row list by shard
    /// ownership (request-major, so each shard sees its sub-requests in
    /// the caller's order), run the inner engine's own `pull_batch` per
    /// shard, scatter back into the caller's request-major layout.
    fn pull_batch(
        &mut self,
        data: &DenseDataset,
        reqs: &[PullRequest<'_>],
        metric: Metric,
        out_sum: &mut Vec<f64>,
        out_sq: &mut Vec<f64>,
    ) {
        let s = self.shards.len();
        let work: usize = reqs
            .iter()
            .map(|r| r.rows.len() * r.coord_ids.len().max(1))
            .sum();
        if s == 1 || work < MIN_PARALLEL_OPS {
            let st = self.shards[0].get_mut().unwrap();
            st.engine.pull_batch(data, reqs, metric, out_sum, out_sq);
            return;
        }
        let total = self.partition.split_batch(data.n, reqs);
        out_sum.clear();
        out_sq.clear();
        out_sum.resize(total, 0.0);
        out_sq.resize(total, 0.0);
        let part = &self.partition;
        let shards = &self.shards;
        self.pool.as_mut().unwrap().run(&|i: usize| {
            let mut guard = shards[i].lock().unwrap();
            let st = &mut *guard;
            let wave = part.wave(i);
            let sub: Vec<PullRequest> = wave.subrequests(reqs).collect();
            st.engine.pull_batch(data, &sub, metric, &mut st.out_sum,
                                 &mut st.out_sq);
        });
        for (i, sh) in self.shards.iter_mut().enumerate() {
            let st = sh.get_mut().unwrap();
            let wave = self.partition.wave(i);
            wave.scatter(&st.out_sum, out_sum);
            wave.scatter(&st.out_sq, out_sq);
        }
    }

    /// Shards are clones of one engine, so the bias is a property of the
    /// inner engine, not of the split: ask shard 0 on behalf of all.
    fn quant_bias(&mut self, data: &DenseDataset, query: &[f32],
                  metric: Metric) -> f64 {
        let st = self.shards[0].get_mut().unwrap();
        st.engine.quant_bias(data, query, metric)
    }

    fn name(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::ScalarEngine;
    use crate::data::synthetic;
    use crate::runtime::native::NativeEngine;
    use crate::util::rng::Rng;

    #[test]
    fn pool_runs_every_worker_each_wave() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let mut pool = ScopedPool::new(4);
        let hits = AtomicUsize::new(0);
        for wave in 1..=3usize {
            pool.run(&|i: usize| {
                hits.fetch_add(i + 1, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), wave * (1 + 2 + 3 + 4));
        }
        assert_eq!(pool.n_workers(), 4);
    }

    #[test]
    fn small_waves_run_inline_and_match() {
        // below MIN_PARALLEL_OPS both paths are the same engine anyway;
        // this pins the empty/tiny-wave plumbing
        let ds = synthetic::gaussian_iid(6, 16, 9);
        let q = ds.row_vec(0);
        let mut sharded = ShardedEngine::new(NativeEngine::default(), 3);
        let mut solo = NativeEngine::default();
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        sharded.partial_sums(&ds, &q, &[1, 3, 5], &[0, 2, 7],
                             Metric::L2Sq, &mut s1, &mut q1);
        solo.partial_sums(&ds, &q, &[1, 3, 5], &[0, 2, 7], Metric::L2Sq,
                          &mut s2, &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
        sharded.partial_sums(&ds, &q, &[], &[1], Metric::L1, &mut s1,
                             &mut q1);
        assert!(s1.is_empty() && q1.is_empty());
    }

    #[test]
    fn big_wave_parallel_path_is_bitwise_identical() {
        // a wave large enough to cross MIN_PARALLEL_OPS so the pool
        // actually dispatches; compare against the single-threaded engine
        let n = 64;
        let d = 128;
        let ds = synthetic::gaussian_iid(n, d, 11);
        let mut rng = Rng::new(12);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> = (0..8 * n as u32).map(|i| i % n as u32)
            .collect();
        let coords: Vec<u32> =
            (0..512).map(|_| rng.below(d) as u32).collect();
        assert!(rows.len() * coords.len() >= MIN_PARALLEL_OPS);
        for shards in [2usize, 3, 5, 8] {
            for metric in [Metric::L2Sq, Metric::L1] {
                let mut sharded =
                    ShardedEngine::new(NativeEngine::default(), shards);
                let mut solo = NativeEngine::default();
                let (mut s1, mut q1) = (Vec::new(), Vec::new());
                let (mut s2, mut q2) = (Vec::new(), Vec::new());
                sharded.partial_sums(&ds, &q, &rows, &coords, metric,
                                     &mut s1, &mut q1);
                solo.partial_sums(&ds, &q, &rows, &coords, metric,
                                  &mut s2, &mut q2);
                assert_eq!(s1, s2, "{metric:?} {shards} shards");
                assert_eq!(q1, q2, "{metric:?} {shards} shards");
                let mut e1 = Vec::new();
                let mut e2 = Vec::new();
                sharded.exact_dists(&ds, &q, &rows, metric, &mut e1);
                solo.exact_dists(&ds, &q, &rows, metric, &mut e2);
                assert_eq!(e1, e2, "{metric:?} {shards} shards exact");
            }
        }
    }

    #[test]
    fn wraps_scalar_engine_too() {
        let ds = synthetic::gaussian_iid(10, 8, 3);
        let q = ds.row_vec(0);
        let rows: Vec<u32> = (0..10).collect();
        let mut sharded = ShardedEngine::new(ScalarEngine, 4);
        let mut solo = ScalarEngine;
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        let (mut s2, mut q2) = (Vec::new(), Vec::new());
        sharded.partial_sums(&ds, &q, &rows, &[1, 2, 3], Metric::L1,
                             &mut s1, &mut q1);
        solo.partial_sums(&ds, &q, &rows, &[1, 2, 3], Metric::L1, &mut s2,
                          &mut q2);
        assert_eq!(s1, s2);
        assert_eq!(q1, q2);
        assert_eq!(sharded.name(), "sharded");
        assert_eq!(sharded.n_shards(), 4);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let e = ShardedEngine::new(NativeEngine::default(), 0);
        assert_eq!(e.n_shards(), 1);
    }

    #[test]
    fn submit_complete_tickets_ride_the_pooled_path_bitwise() {
        // the split API over the sharded engine: the default submit
        // resolves through the pooled pull_batch, so a >threshold wave
        // crosses the dispatch path and must still match the
        // single-threaded engine exactly, with out-of-order completion
        let n = 64;
        let d = 128;
        let ds = synthetic::gaussian_iid(n, d, 19);
        let mut rng = Rng::new(20);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let rows: Vec<u32> =
            (0..8 * n as u32).map(|i| i % n as u32).collect();
        let coords: Vec<u32> =
            (0..512).map(|_| rng.below(d) as u32).collect();
        assert!(rows.len() * coords.len() >= MIN_PARALLEL_OPS);
        let mut sharded = ShardedEngine::new(NativeEngine::default(), 3);
        assert!(!sharded.pipelined(), "pool waves resolve at submit");
        let t1 = sharded.submit_partial_sums(&ds, &q, &rows, &coords,
                                             Metric::L2Sq);
        let t2 = sharded.submit_exact_dists(&ds, &q, &rows, Metric::L1);
        let mut d2 = Vec::new();
        sharded.complete_dists(t2, &mut d2);
        let (mut s1, mut q1) = (Vec::new(), Vec::new());
        sharded.complete_sums(t1, &mut s1, &mut q1);
        let mut solo = NativeEngine::default();
        let (mut ws, mut wq) = (Vec::new(), Vec::new());
        solo.partial_sums(&ds, &q, &rows, &coords, Metric::L2Sq, &mut ws,
                          &mut wq);
        assert_eq!(s1, ws);
        assert_eq!(q1, wq);
        let mut wd = Vec::new();
        solo.exact_dists(&ds, &q, &rows, Metric::L1, &mut wd);
        assert_eq!(d2, wd);
    }
}
