//! Opt-in int8 quantized sampling tier (`[engine] quantized = true`).
//!
//! A [`QuantShadow`] is an int8 affine (scale + zero-point per row)
//! shadow copy of a [`DenseDataset`]: 4× smaller rows, so the random
//! gathers of sampled pull waves touch 4× less memory. The shadow is
//! used **only** for `partial_sums` / `pull_batch` waves — the bandit's
//! noisy estimates, which already carry confidence intervals — while
//! `exact_dists` (candidate rescoring, MAX_PULLS collapse, final
//! answers) always reads the exact f32 rows. Per-value reconstruction
//! error is bounded by `scale_r / 2`, and [`QuantShadow::theta_bias`]
//! converts that into a worst-case per-coordinate estimate bias in
//! θ-units which the caller adds to every confidence half-width via
//! `BanditParams::bias` — the PAC accounting then absorbs quantization
//! error exactly like sampling noise (see `coordinator::bandit`).
//!
//! Determinism: dequantize-and-accumulate runs in f64 per row, in
//! coordinate order, with no lane-width dependence — so for the
//! quantized tier, sharded / remote-less substrates that split waves by
//! row stay bitwise-identical to solo, same as the f32 kernel tiers.
//!
//! Shadows are built once per dataset per process: a process-wide cache
//! keyed by the dataset's buffer identity (pointer, shape, first/last
//! value bits) hands out `Arc`s, so the per-shard engine clones of
//! `ShardedEngine` share one shadow instead of quantizing the dataset
//! once per shard.

#![deny(missing_docs)]

use std::fmt;
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::data::dense::{DenseDataset, Metric};

/// Int8 affine shadow copy of a dense dataset: per row `r`,
/// `x̂ = scale[r] · code + offset[r]` reconstructs the value to within
/// `scale[r] / 2`.
pub struct QuantShadow {
    /// Row count (matches the source dataset).
    pub n: usize,
    /// Dimensions (matches the source dataset).
    pub d: usize,
    /// Row-major int8 codes, `n * d`.
    codes: Vec<i8>,
    /// Per-row dequantization scale.
    scale: Vec<f32>,
    /// Per-row dequantization offset (folds in the zero point).
    offset: Vec<f32>,
    /// Max `|x|` over the source dataset (for the ℓ2² bias bound).
    max_abs: f32,
    /// Max over rows of `scale_r / 2` — the per-value error bound.
    max_err: f32,
}

impl QuantShadow {
    /// Quantize `data`: per-row min/max affine mapping onto `[-128, 127]`.
    /// Constant rows get `scale = 0` and reconstruct exactly.
    pub fn build(data: &DenseDataset) -> QuantShadow {
        let (n, d) = (data.n, data.d);
        let mut codes = Vec::with_capacity(n * d);
        let mut scale = Vec::with_capacity(n);
        let mut offset = Vec::with_capacity(n);
        let mut max_abs = 0f32;
        let mut max_err = 0f32;
        for r in 0..n {
            let row = data.row(r);
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in row {
                lo = lo.min(x);
                hi = hi.max(x);
                max_abs = max_abs.max(x.abs());
            }
            let sc = if d == 0 || hi <= lo { 0.0 } else { (hi - lo) / 255.0 };
            if sc > 0.0 {
                for &x in row {
                    let u = ((x - lo) / sc).round();
                    codes.push((u - 128.0).clamp(-128.0, 127.0) as i8);
                }
            } else {
                codes.resize(codes.len() + d, 0);
            }
            scale.push(sc);
            // x̂ = sc·code + offset with code = round((x − lo)/sc) − 128
            // ⇒ offset = lo + 128·sc, |x̂ − x| ≤ sc/2
            offset.push(if sc > 0.0 { lo + 128.0 * sc } else { lo });
            max_err = max_err.max(sc / 2.0);
        }
        QuantShadow { n, d, codes, scale, offset, max_abs, max_err }
    }

    /// Reconstructed value `x̂` at `(row, col)` — test/debug helper.
    pub fn reconstruct(&self, row: usize, col: usize) -> f32 {
        self.scale[row] * self.codes[row * self.d + col] as f32
            + self.offset[row]
    }

    /// The per-value reconstruction error bound `max_r scale_r / 2`.
    pub fn max_err(&self) -> f32 {
        self.max_err
    }

    /// Sampled partial moments `(Σ v, Σ v²)` of
    /// `v = metric.coord(x̂[coords[i]], qg[i])` over the dequantized row.
    /// f64 accumulation in coordinate order: deterministic and row-local,
    /// so row-split substrates keep bitwise parity on this tier.
    pub fn partial_row(&self, row: usize, qg: &[f32], coords: &[u32],
                       metric: Metric) -> (f64, f64) {
        let codes = &self.codes[row * self.d..(row + 1) * self.d];
        let sc = self.scale[row];
        let off = self.offset[row];
        let mut s = 0f64;
        let mut q = 0f64;
        for (i, &j) in coords.iter().enumerate() {
            let xh = sc * codes[j as usize] as f32 + off;
            let v = metric.coord(xh, qg[i]) as f64;
            s += v;
            q += v * v;
        }
        (s, q)
    }

    /// Worst-case bias, in θ-units (per-coordinate distance), that
    /// quantization can add to a sampled pull estimate against `query`.
    ///
    /// With per-value error `e = max_err`:
    /// * ℓ1: `||x̂−q| − |x−q|| ≤ e` per coordinate;
    /// * ℓ2²: `|(x̂−q)² − (x−q)²| = |x̂−x| · |x̂+x−2q|
    ///   ≤ e · (2|x−q| + e) ≤ e · (2(A_data + A_q) + e)` where `A` are
    ///   max absolute values of the data and the query.
    ///
    /// The caller folds this into `BanditParams::bias`, widening every
    /// non-exact confidence interval: UCB/LCB stay valid bounds on the
    /// true θ, so elimination and the PAC stop rule absorb the error.
    pub fn theta_bias(&self, query: &[f32], metric: Metric) -> f64 {
        let e = self.max_err as f64;
        if e == 0.0 {
            return 0.0;
        }
        match metric {
            Metric::L1 => e,
            Metric::L2Sq => {
                let aq = query
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()))
                    as f64;
                let span = self.max_abs as f64 + aq;
                e * (2.0 * span + e)
            }
        }
    }
}

impl fmt::Debug for QuantShadow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("QuantShadow")
            .field("n", &self.n)
            .field("d", &self.d)
            .field("max_abs", &self.max_abs)
            .field("max_err", &self.max_err)
            .finish()
    }
}

/// Cache key: dataset buffer identity. The value-bit fingerprints guard
/// against an address being reused by a different same-shape dataset
/// after the original was dropped.
type CacheKey = (usize, usize, usize, u32, u32);

fn cache_key(data: &DenseDataset) -> CacheKey {
    let raw = data.raw();
    (
        raw.as_ptr() as usize,
        data.n,
        data.d,
        raw.first().map_or(0, |v| v.to_bits()),
        raw.last().map_or(0, |v| v.to_bits()),
    )
}

static CACHE: OnceLock<Mutex<Vec<(CacheKey, Weak<QuantShadow>)>>> =
    OnceLock::new();

/// The shared shadow for `data`: built on first request, then handed out
/// as clones of one `Arc` for the dataset's lifetime (the cache holds
/// `Weak`s and drops dead entries on every lookup).
pub fn shadow_for(data: &DenseDataset) -> Arc<QuantShadow> {
    let key = cache_key(data);
    let cache = CACHE.get_or_init(|| Mutex::new(Vec::new()));
    let mut guard = cache.lock().unwrap();
    guard.retain(|(_, w)| w.strong_count() > 0);
    if let Some((_, w)) = guard.iter().find(|(k, _)| *k == key) {
        if let Some(shadow) = w.upgrade() {
            return shadow;
        }
    }
    let shadow = Arc::new(QuantShadow::build(data));
    guard.push((key, Arc::downgrade(&shadow)));
    shadow
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    #[test]
    fn reconstruction_error_is_bounded_by_half_scale() {
        let mut rng = Rng::new(0x0111);
        for &scale in &[1.0f32, 100.0, 1000.0] {
            let n = 20;
            let d = 64;
            let mut ds = DenseDataset::zeros(n, d);
            for r in 0..n {
                for v in ds.row_mut(r) {
                    *v = rng.gaussian() as f32 * scale;
                }
            }
            let sh = QuantShadow::build(&ds);
            for r in 0..n {
                for c in 0..d {
                    let err = (sh.reconstruct(r, c) - ds.get(r, c)).abs();
                    assert!(
                        err <= sh.max_err() + 1e-6,
                        "row {r} col {c}: err {err} > bound {}",
                        sh.max_err()
                    );
                }
            }
        }
    }

    #[test]
    fn constant_and_tiny_rows_reconstruct_exactly() {
        // constant row (scale = 0) and a d = 1 dataset
        let ds = DenseDataset::new(2, 3,
                                   vec![7.5, 7.5, 7.5, -2.0, 0.0, 2.0]);
        let sh = QuantShadow::build(&ds);
        for c in 0..3 {
            assert_eq!(sh.reconstruct(0, c), 7.5);
        }
        let one = DenseDataset::new(1, 1, vec![42.0]);
        let sh1 = QuantShadow::build(&one);
        assert_eq!(sh1.reconstruct(0, 0), 42.0);
        assert_eq!(sh1.max_err(), 0.0);
    }

    #[test]
    fn theta_bias_bounds_observed_estimate_error() {
        // empirical check of the bias algebra: the per-pull estimate off
        // the shadow never strays from the exact-f32 estimate by more
        // than theta_bias, across metrics, magnitudes and pull sizes
        let mut rng = Rng::new(0x0222);
        for &mag in &[1.0f32, 500.0] {
            let n = 30;
            let d = 128;
            let mut ds = DenseDataset::zeros(n, d);
            for r in 0..n {
                for v in ds.row_mut(r) {
                    *v = rng.gaussian() as f32 * mag;
                }
            }
            let sh = QuantShadow::build(&ds);
            let query: Vec<f32> =
                (0..d).map(|_| rng.gaussian() as f32 * mag).collect();
            for metric in [Metric::L2Sq, Metric::L1] {
                let bias = sh.theta_bias(&query, metric);
                for &t in &[1usize, 16, 128] {
                    let coords: Vec<u32> =
                        (0..t).map(|_| rng.below(d) as u32).collect();
                    let qg: Vec<f32> = coords
                        .iter()
                        .map(|&j| query[j as usize])
                        .collect();
                    for r in 0..n {
                        let (sq, _) =
                            sh.partial_row(r, &qg, &coords, metric);
                        let mut se = 0f64;
                        for (i, &j) in coords.iter().enumerate() {
                            se += metric.coord(ds.get(r, j as usize),
                                               qg[i])
                                as f64;
                        }
                        let td = t as f64;
                        assert!(
                            (sq / td - se / td).abs() <= bias + 1e-9,
                            "{metric:?} mag={mag} t={t} row {r}: \
                             |{} - {}| > {bias}",
                            sq / td,
                            se / td
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn shadow_cache_shares_one_arc_per_dataset() {
        let ds = synthetic::gaussian_iid(8, 16, 0x0333);
        let a = shadow_for(&ds);
        let b = shadow_for(&ds);
        assert!(Arc::ptr_eq(&a, &b));
        let other = synthetic::gaussian_iid(8, 16, 0x0444);
        let c = shadow_for(&other);
        assert!(!Arc::ptr_eq(&a, &c));
    }
}
