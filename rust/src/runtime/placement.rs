//! Shard placement for replicated rings: which endpoints serve which
//! logical shard, plus the per-endpoint retry bookkeeping the failover
//! path uses.
//!
//! PR 3's ring contract was *fixed*: endpoint `i` of the `--remote` list
//! served shard `i` of `S`, and a single endpoint death turned every
//! touching wave into a hard error. A [`PlacementMap`] generalizes that
//! to an **ordered replica list per logical shard**: the endpoint-list
//! syntax grows a `|` separator (`primary|replica|...` within one
//! shard's slot, shards still separated by commas), so
//!
//! ```text
//! [engine]
//! remote = "10.0.0.1:7979|10.0.1.1:7979, 10.0.0.2:7979|10.0.1.2:7979"
//! ```
//!
//! is a 2-shard ring with two replicas per shard. Every replica of shard
//! `i` must serve exactly `shard_range(i, n, S)` of the same dataset
//! (verified at handshake, exactly like the unreplicated ring), which is
//! what makes failover answer **bitwise-identically**: any replica of a
//! shard computes the same jobs with the same kernel.
//!
//! Retry policy: each endpoint carries an [`EndpointState`]. A failed
//! connect, I/O error or wire `Error` reply records a failure, putting
//! the endpoint on a blacklist for an exponentially growing backoff
//! window ([`RetryPolicy`]); a successful reconnect + handshake heals it
//! completely. All state transitions take an explicit `now` so the
//! policy is unit-testable without a clock.

#![deny(missing_docs)]

use std::time::{Duration, Instant};

/// Per-shard ordered replica lists: `shards[i]` holds the endpoints that
/// (claim to) serve logical shard `i`, preferred first. Parsed from the
/// `[engine] remote` / `--remote` endpoint syntax by
/// [`PlacementMap::parse`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementMap {
    shards: Vec<Vec<String>>,
}

impl PlacementMap {
    /// Build a placement from one spec per logical shard, each spec an
    /// ordered `|`-separated replica list (a bare `host:port` is a
    /// single-replica shard, so unreplicated PR 3 rings parse
    /// unchanged). Empty replica entries and duplicate endpoints within
    /// one shard are rejected.
    pub fn parse(specs: &[String]) -> Result<PlacementMap, String> {
        if specs.is_empty() {
            return Err("remote engine needs at least one shard endpoint"
                .into());
        }
        let mut shards = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let reps: Vec<String> = spec
                .split('|')
                .map(|e| e.trim().to_string())
                .collect();
            if reps.iter().any(|e| e.is_empty()) {
                return Err(format!(
                    "shard {i}: empty replica endpoint in '{spec}'"));
            }
            for (a, ea) in reps.iter().enumerate() {
                if reps[..a].contains(ea) {
                    return Err(format!(
                        "shard {i}: endpoint {ea} listed twice in '{spec}'"));
                }
            }
            shards.push(reps);
        }
        Ok(PlacementMap { shards })
    }

    /// Number of logical shards (the ring size `S`).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Ordered replica endpoints of logical shard `shard`.
    pub fn replicas(&self, shard: usize) -> &[String] {
        &self.shards[shard]
    }

    /// Total endpoint count across every shard's replica list.
    pub fn n_endpoints(&self) -> usize {
        self.shards.iter().map(|r| r.len()).sum()
    }
}

/// Backoff schedule applied to a failing endpoint: the `f`-th
/// consecutive failure blacklists it for
/// `min(backoff_base * 2^(f-1), backoff_max)`.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// blacklist window after the first failure (doubles per failure)
    pub backoff_base: Duration,
    /// cap on the blacklist window
    pub backoff_max: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            backoff_base: Duration::from_millis(250),
            backoff_max: Duration::from_secs(4),
        }
    }
}

impl RetryPolicy {
    /// Blacklist window after `fails` consecutive failures (>= 1).
    pub fn backoff(&self, fails: u32) -> Duration {
        let exp = fails.saturating_sub(1).min(16);
        let w = self
            .backoff_base
            .checked_mul(1u32 << exp)
            .unwrap_or(self.backoff_max);
        w.min(self.backoff_max)
    }
}

/// Failure bookkeeping for one endpoint: consecutive-failure count and
/// the blacklist deadline. Heals fully on [`EndpointState::record_success`]
/// (a working reconnect + handshake), so a restarted shard server is
/// preferred again as soon as its backoff window has passed once.
#[derive(Clone, Copy, Debug, Default)]
pub struct EndpointState {
    fails: u32,
    down_until: Option<Instant>,
}

impl EndpointState {
    /// May this endpoint be dialed at `now`? (Not currently blacklisted.)
    pub fn eligible(&self, now: Instant) -> bool {
        match self.down_until {
            None => true,
            Some(t) => now >= t,
        }
    }

    /// Record a failed connect / request at `now`: bumps the consecutive
    /// count and extends the blacklist per `policy`.
    pub fn record_failure(&mut self, policy: &RetryPolicy, now: Instant) {
        self.fails = self.fails.saturating_add(1);
        self.down_until = Some(now + policy.backoff(self.fails));
    }

    /// Record a working reconnect: clears the failure count and the
    /// blacklist (the heal half of the failover contract).
    pub fn record_success(&mut self) {
        self.fails = 0;
        self.down_until = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn bare_endpoints_parse_as_single_replica_shards() {
        let p = PlacementMap::parse(&sv(&["a:1", "b:2"])).unwrap();
        assert_eq!(p.n_shards(), 2);
        assert_eq!(p.replicas(0), &["a:1".to_string()]);
        assert_eq!(p.replicas(1), &["b:2".to_string()]);
        assert_eq!(p.n_endpoints(), 2);
    }

    #[test]
    fn pipe_separated_replicas_parse_in_order() {
        let p = PlacementMap::parse(&sv(&["a:1|b:1 | c:1", "d:2"])).unwrap();
        assert_eq!(p.n_shards(), 2);
        assert_eq!(p.replicas(0),
                   &["a:1".to_string(), "b:1".to_string(), "c:1".to_string()]);
        assert_eq!(p.n_endpoints(), 4);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(PlacementMap::parse(&[]).is_err());
        assert!(PlacementMap::parse(&sv(&["a:1|"])).is_err());
        assert!(PlacementMap::parse(&sv(&["|a:1"])).is_err());
        let err = PlacementMap::parse(&sv(&["a:1|a:1"])).unwrap_err();
        assert!(err.contains("twice"), "got: {err}");
    }

    #[test]
    fn backoff_doubles_to_the_cap() {
        let p = RetryPolicy {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_millis(450),
        };
        assert_eq!(p.backoff(1), Duration::from_millis(100));
        assert_eq!(p.backoff(2), Duration::from_millis(200));
        assert_eq!(p.backoff(3), Duration::from_millis(400));
        assert_eq!(p.backoff(4), Duration::from_millis(450));
        assert_eq!(p.backoff(40), Duration::from_millis(450));
    }

    #[test]
    fn backoff_saturates_at_the_cap_without_overflow() {
        // huge consecutive-failure counts must clamp to the cap, never
        // overflow the shift or the Duration multiply
        let p = RetryPolicy {
            backoff_base: Duration::from_secs(1),
            backoff_max: Duration::from_secs(8),
        };
        for fails in [17u32, 31, 32, 64, 1_000, u32::MAX] {
            assert_eq!(p.backoff(fails), Duration::from_secs(8),
                       "fails = {fails}");
        }
        // monotone nondecreasing up to saturation
        let mut prev = Duration::ZERO;
        for fails in 1..64u32 {
            let w = p.backoff(fails);
            assert!(w >= prev, "backoff shrank at fails = {fails}");
            prev = w;
        }
        // degenerate call: fails = 0 behaves like the first failure
        assert_eq!(p.backoff(0), p.backoff(1));
    }

    #[test]
    fn heal_then_stale_blacklist_restarts_from_base() {
        // a reconnect heal racing a concurrent blacklist: the failure
        // observed *after* the heal must restart the schedule at the
        // base window, not resume the pre-heal doubled one
        let policy = RetryPolicy {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(4),
        };
        let t0 = Instant::now();
        let mut st = EndpointState::default();
        st.record_failure(&policy, t0);
        st.record_failure(&policy, t0);
        st.record_failure(&policy, t0); // window now 400ms
        assert!(!st.eligible(t0 + Duration::from_millis(399)));
        st.record_success(); // reconnect heals completely
        assert!(st.eligible(t0));
        // the racing failure (e.g. a wave that was already in flight on
        // the old dead conn) lands after the heal
        st.record_failure(&policy, t0);
        assert!(!st.eligible(t0 + Duration::from_millis(99)));
        assert!(st.eligible(t0 + Duration::from_millis(100)),
                "post-heal failure must blacklist for base, not 800ms");
    }

    #[test]
    fn regressed_now_never_panics_and_keeps_the_state_sane() {
        // explicit-`now` monotonicity: callers sample Instant::now() at
        // different points, so a `now` older than a previous call's must
        // be handled (no panic, no underflow), just with the window
        // anchored at whatever `now` the caller passed
        let policy = RetryPolicy {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(1),
        };
        let t0 = Instant::now();
        let t_late = t0 + Duration::from_secs(10);
        let mut st = EndpointState::default();
        st.record_failure(&policy, t_late);
        // probing with an older timestamp: still blacklisted, no panic
        assert!(!st.eligible(t0));
        // a regressed failure timestamp re-anchors the (doubled) window
        // at the older now — eligible again sooner, but never panicking
        st.record_failure(&policy, t0);
        assert!(!st.eligible(t0 + Duration::from_millis(199)));
        assert!(st.eligible(t0 + Duration::from_millis(200)));
        assert!(st.eligible(t_late));
    }

    #[test]
    fn endpoint_state_blacklists_and_heals() {
        let policy = RetryPolicy {
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_secs(1),
        };
        let t0 = Instant::now();
        let mut st = EndpointState::default();
        assert!(st.eligible(t0));
        st.record_failure(&policy, t0);
        assert!(!st.eligible(t0 + Duration::from_millis(99)));
        assert!(st.eligible(t0 + Duration::from_millis(100)));
        // second consecutive failure doubles the window
        st.record_failure(&policy, t0);
        assert!(!st.eligible(t0 + Duration::from_millis(199)));
        assert!(st.eligible(t0 + Duration::from_millis(200)));
        // a working reconnect heals completely
        st.record_success();
        assert!(st.eligible(t0));
    }
}
