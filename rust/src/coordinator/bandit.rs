//! BMO UCB — Algorithm 1 of the paper, generalized with the batched pull
//! policy of Appendix D-A and the PAC stopping rule of Theorem 2.
//!
//! The algorithm is UCB1 over the Monte Carlo boxes with one structural
//! twist: an arm pulled `MAX_PULLS` times has its mean *computed exactly*
//! and its confidence interval collapsed to 0 — which is what makes exact
//! identification possible with a UCB-style rule (§II-B) and caps the work
//! per arm at ~2·MAX_PULLS coordinate operations.
//!
//! Faithful mode (`PullPolicy::faithful()`): one arm, one pull per
//! iteration, exactly Algorithm 1. Batched mode (`PullPolicy::batched()`):
//! init 32 pulls/arm, then the `round_arms` lowest-LCB arms pulled
//! `round_pulls` times per round — the paper's practical implementation
//! ("the top 32 arms are pulled 256 times each", Appendix D-A).
//!
//! Selection state lives in a lazy binary heap keyed by LCB with
//! per-arm version stamps, giving the paper's O(log n) per-iteration
//! overhead.

#![deny(missing_docs)]

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::coordinator::arms::ArmSet;
use crate::metrics::{Counter, RunMetrics};
use crate::util::rng::Rng;

/// How σ (the sub-Gaussian scale in Eq. 3) is obtained.
#[derive(Clone, Copy, Debug)]
pub enum SigmaMode {
    /// Known bound, as in Theorem 1's statement. σ is in θ-units.
    Fixed(f64),
    /// Appendix D-A: per-arm running empirical variance, pooled estimate
    /// while an arm has too few samples.
    Empirical,
}

/// Pull-scheduling policy.
#[derive(Clone, Copy, Debug)]
pub struct PullPolicy {
    /// pulls given to every arm up front
    pub init_pulls: u64,
    /// arms selected per round (lowest LCB first)
    pub round_arms: usize,
    /// pulls per selected arm per round
    pub round_pulls: u64,
}

impl PullPolicy {
    /// Exactly Algorithm 1: single arm, single pull.
    pub fn faithful() -> Self {
        PullPolicy { init_pulls: 1, round_arms: 1, round_pulls: 1 }
    }

    /// Appendix D-A practical policy.
    pub fn batched() -> Self {
        PullPolicy { init_pulls: 32, round_arms: 32, round_pulls: 256 }
    }
}

/// Full parameter set of one BMO UCB run.
#[derive(Clone, Debug)]
pub struct BanditParams {
    /// number of best arms to identify
    pub k: usize,
    /// target error probability δ
    pub delta: f64,
    /// how the sub-Gaussian scale σ is obtained (Eq. 3)
    pub sigma: SigmaMode,
    /// PAC slack ε (Theorem 2); 0.0 = exact identification (Theorem 1)
    pub epsilon: f64,
    /// pull-scheduling policy (faithful Algorithm 1 vs batched D-A)
    pub policy: PullPolicy,
    /// worst-case systematic bias of sampled estimates, in θ-units —
    /// nonzero when the engine computes pulls approximately (the int8
    /// quantized tier reports its reconstruction-error bound through
    /// `PullEngine::quant_bias`). Added to every non-exact confidence
    /// half-width, so UCB/LCB remain valid bounds on the true θ and
    /// both the elimination rule and the Theorem 2 PAC stop rule absorb
    /// the approximation. Exact evaluations are never biased (their
    /// intervals still collapse to 0), so runs stay correct and
    /// terminating even when `bias` dwarfs ε — they just lose the
    /// sampling shortcut for arms closer than the bias.
    pub bias: f64,
}

impl Default for BanditParams {
    fn default() -> Self {
        BanditParams {
            k: 1,
            delta: 0.01,
            sigma: SigmaMode::Empirical,
            epsilon: 0.0,
            policy: PullPolicy::batched(),
            bias: 0.0,
        }
    }
}

/// Result of one BMO UCB run.
#[derive(Clone, Debug)]
pub struct BanditResult {
    /// winning arms in emission order (increasing θ), with final means
    pub best: Vec<(usize, f64)>,
    /// cost accounting of the run
    pub metrics: RunMetrics,
    /// per-arm pull counts (diagnostics / ablation benches)
    pub pulls_per_arm: Vec<u64>,
    /// per-arm exact-evaluated flag
    pub exact_per_arm: Vec<bool>,
}

/// f64 ordered for the heap (total order; NaN never enters).
#[derive(Clone, Copy, PartialEq)]
struct OrdF64(f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

#[derive(Clone, Debug)]
struct ArmState {
    pulls: u64,
    sum: f64,
    sum_sq: f64,
    mean: f64,
    exact: bool,
    removed: bool,
    version: u32,
}

impl ArmState {
    fn variance(&self) -> Option<f64> {
        if self.exact || self.pulls < 2 {
            return None;
        }
        let n = self.pulls as f64;
        let var = (self.sum_sq - self.sum * self.sum / n) / (n - 1.0);
        Some(var.max(0.0))
    }
}

/// What the caller of [`BmoUcb::begin_round`] must do to advance the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundAction {
    /// All `k` arms were emitted (or the arm set is exhausted): the run is
    /// complete; read it off with [`BmoUcb::result`].
    Done,
    /// The bandit staged a uniform pull of `t` samples for each arm in
    /// [`BmoUcb::pending_arms`]. Execute it — via [`ArmSet::pull_batch`]
    /// for a standalone run, or coalesced with other queries through
    /// `PullEngine::pull_batch` — and feed the per-arm (Σx, Σx²) back with
    /// [`BmoUcb::end_round`].
    Pull { t: u64 },
}

/// The BMO UCB state machine.
///
/// Two ways to drive it: [`BmoUcb::run`] owns the whole loop for a single
/// query, while the [`BmoUcb::begin_round`] / [`BmoUcb::end_round`] pair
/// exposes one scheduling round at a time so a multi-query driver
/// (`coordinator::knn::knn_batch_dense`) can advance many instances in
/// lockstep and coalesce their staged pulls into one engine pass per
/// round. `run` is implemented on top of the pair, so both paths execute
/// identical pull sequences.
pub struct BmoUcb {
    params: BanditParams,
    states: Vec<ArmState>,
    /// min-heap on LCB with lazy (stale-version) entries
    heap: BinaryHeap<Reverse<(OrdF64, u32, u32)>>, // (lcb, version, arm)
    /// pooled within-arm variance numerator / denominator (for Empirical
    /// sigma when an arm has too few pulls)
    pooled_num: f64,
    pooled_den: f64,
    /// ln(2·n·MAX_PULLS/δ) — the union-bound log term of Lemma 1
    log_term: f64,
    /// winning arms in emission order (increasing θ)
    best: Vec<(usize, f64)>,
    /// arms selected in the current round (returned to the heap at
    /// end_round)
    selected: Vec<usize>,
    /// arms of the staged uniform pull awaiting end_round
    pending: Vec<usize>,
    pending_t: u64,
    /// true while the staged pull is the init round (heap not yet built)
    init_heap_pending: bool,
    init_done: bool,
    finished: bool,
    rounds: u64,
    exact_evals: u64,
    t0: Option<Instant>,
    start_units: u64,
}

const MIN_PULLS_FOR_OWN_VAR: u64 = 10;
const SIGMA2_FLOOR: f64 = 1e-12;

impl BmoUcb {
    /// Fresh state machine over `arms.n_arms()` arms (no pulls issued
    /// yet — the first [`BmoUcb::begin_round`] stages the init round).
    pub fn new<A: ArmSet>(arms: &A, params: BanditParams) -> Self {
        let n = arms.n_arms();
        assert!(params.k <= n, "k={} > n_arms={}", params.k, n);
        assert!(params.delta > 0.0 && params.delta < 1.0);
        let max_pulls_bound =
            (0..n).map(|i| arms.max_pulls(i)).max().unwrap_or(1).max(1);
        let log_term =
            (2.0 * n as f64 * max_pulls_bound as f64 / params.delta).ln();
        let k = params.k;
        BmoUcb {
            params,
            states: vec![
                ArmState {
                    pulls: 0,
                    sum: 0.0,
                    sum_sq: 0.0,
                    mean: 0.0,
                    exact: false,
                    removed: false,
                    version: 0,
                };
                n
            ],
            heap: BinaryHeap::with_capacity(n * 2),
            pooled_num: 0.0,
            pooled_den: 0.0,
            log_term,
            best: Vec::with_capacity(k),
            selected: Vec::new(),
            pending: Vec::new(),
            pending_t: 0,
            init_heap_pending: false,
            init_done: false,
            finished: false,
            rounds: 0,
            exact_evals: 0,
            t0: None,
            start_units: 0,
        }
    }

    fn sigma2(&self, arm: usize) -> f64 {
        match self.params.sigma {
            SigmaMode::Fixed(s) => (s * s).max(SIGMA2_FLOOR),
            SigmaMode::Empirical => {
                let st = &self.states[arm];
                let pooled = if self.pooled_den > 0.0 {
                    self.pooled_num / self.pooled_den
                } else {
                    f64::INFINITY // no info yet: infinite CI
                };
                let v = match st.variance() {
                    Some(v) if st.pulls >= MIN_PULLS_FOR_OWN_VAR => {
                        // guard against degenerate zero sample variance
                        // (e.g. constant coordinate distances)
                        if v > 0.0 { v } else { pooled.max(SIGMA2_FLOOR) }
                    }
                    _ => pooled,
                };
                v.max(SIGMA2_FLOOR)
            }
        }
    }

    /// Half-width C_{i,T_i} (Eq. 3), plus the engine's systematic
    /// estimate bias (`BanditParams::bias`) for non-exact arms — the
    /// sampling interval covers the noise, the bias term covers the
    /// approximation, so mean ± ci still bounds the true θ.
    fn ci(&self, arm: usize) -> f64 {
        let st = &self.states[arm];
        if st.exact {
            return 0.0;
        }
        if st.pulls == 0 {
            return f64::INFINITY;
        }
        let s2 = self.sigma2(arm);
        if !s2.is_finite() {
            return f64::INFINITY;
        }
        (2.0 * s2 * self.log_term / st.pulls as f64).sqrt()
            + self.params.bias
    }

    fn lcb(&self, arm: usize) -> f64 {
        let c = self.ci(arm);
        if c.is_infinite() {
            f64::NEG_INFINITY
        } else {
            self.states[arm].mean - c
        }
    }

    fn ucb(&self, arm: usize) -> f64 {
        let c = self.ci(arm);
        if c.is_infinite() {
            f64::INFINITY
        } else {
            self.states[arm].mean + c
        }
    }

    fn push_heap(&mut self, arm: usize) {
        let lcb = self.lcb(arm);
        let v = self.states[arm].version;
        self.heap.push(Reverse((OrdF64(lcb), v, arm as u32)));
    }

    /// Pop the freshest lowest-LCB live arm.
    fn pop_fresh(&mut self) -> Option<usize> {
        while let Some(Reverse((_, v, a))) = self.heap.pop() {
            let st = &self.states[a as usize];
            if !st.removed && st.version == v {
                return Some(a as usize);
            }
        }
        None
    }

    /// Peek the lowest live LCB without consuming it.
    fn peek_fresh_lcb(&mut self) -> f64 {
        loop {
            match self.heap.peek() {
                None => return f64::INFINITY,
                Some(&Reverse((OrdF64(lcb), v, a))) => {
                    let st = &self.states[a as usize];
                    if !st.removed && st.version == v {
                        return lcb;
                    }
                    self.heap.pop();
                }
            }
        }
    }

    fn record_samples(&mut self, arm: usize, t: u64, sum: f64,
                      sum_sq_est: f64) {
        let st = &mut self.states[arm];
        // update pooled variance bookkeeping: remove old contribution
        if let Some(v) = st.variance() {
            self.pooled_num -= v * (st.pulls - 1) as f64;
            self.pooled_den -= (st.pulls - 1) as f64;
        }
        st.pulls += t;
        st.sum += sum;
        st.sum_sq += sum_sq_est;
        st.mean = st.sum / st.pulls as f64;
        st.version += 1;
        if let Some(v) = st.variance() {
            self.pooled_num += v * (st.pulls - 1) as f64;
            self.pooled_den += (st.pulls - 1) as f64;
        }
    }

    fn set_exact(&mut self, arm: usize, theta: f64) {
        let st = &mut self.states[arm];
        if let Some(v) = st.variance() {
            self.pooled_num -= v * (st.pulls - 1) as f64;
            self.pooled_den -= (st.pulls - 1) as f64;
        }
        st.exact = true;
        st.mean = theta;
        st.version += 1;
    }

    /// Should the currently-best arm be emitted? (Alg 1 line 7, plus the
    /// Theorem 2 PAC rule, plus an exact-tie tiebreak.)
    fn emit_condition(&self, best: usize, second_lcb: f64) -> bool {
        let ucb = self.ucb(best);
        if ucb < second_lcb {
            return true;
        }
        // exact ties: both intervals are points; emitting either is
        // correct (the paper's θ_(k)=θ_(k+1) remark)
        if self.states[best].exact && ucb <= second_lcb {
            return true;
        }
        // PAC rule: the *selected* arm's interval is already ε/2-narrow
        if self.params.epsilon > 0.0 && self.ci(best) < self.params.epsilon / 2.0
        {
            return true;
        }
        false
    }

    /// Arms of the pull staged by the last [`BmoUcb::begin_round`] that
    /// returned [`RoundAction::Pull`].
    pub fn pending_arms(&self) -> &[usize] {
        &self.pending
    }

    /// Predict a **superset** of the likely round-t+1 uniform pull set,
    /// for speculative cross-round pipelining. Call between a
    /// [`BmoUcb::begin_round`] that returned [`RoundAction::Pull`] and the
    /// matching [`BmoUcb::end_round`]; returns `(arms, t)` — candidate
    /// arms for the *next* staged pull and its uniform pull count — or
    /// `None` when the next round is unpredictable (init round in flight,
    /// run finished, or no candidate has `t` pulls of cap headroom).
    ///
    /// The prediction is the current pending arms (UCB arm state drifts
    /// little between rounds, so most survive selection again) plus the
    /// heap's current lowest-LCB arms — the exact candidates the next
    /// selection will pop first — each filtered for cap headroom so a
    /// speculated pull can never overshoot `max_pulls`. A superset is the
    /// right shape because a speculative wave's per-row results are
    /// position-independent: the driver confirms by matching the real
    /// round's rows as a *subset* of the speculated rows and gathers
    /// through the permutation, so over-predicting costs only wasted
    /// shard work, never correctness.
    ///
    /// Observably pure: heap reads pop fresh entries and re-push
    /// identical keys (pop order is uniquely determined by the strict
    /// total order on `(lcb, version, arm)`), no arm state changes, and
    /// no rng is drawn — so calling this never perturbs the run and
    /// speculation-off stays byte-for-byte identical.
    pub fn predict_next_pull<A: ArmSet>(&mut self, arms: &A)
                                        -> Option<(Vec<usize>, u64)> {
        if self.finished || self.init_heap_pending || self.pending.is_empty()
        {
            return None;
        }
        let t = self.params.policy.round_pulls;
        if t == 0 {
            return None;
        }
        let mut pred: Vec<usize> = Vec::new();
        // pending arms: headroom after the in-flight pull lands
        for &a in &self.pending {
            let left = arms
                .max_pulls(a)
                .saturating_sub(self.states[a].pulls)
                .saturating_sub(self.pending_t);
            if left >= t {
                pred.push(a);
            }
        }
        // the heap's current lowest-LCB arms — what the next selection
        // pops first (read via pop-fresh + re-push of identical keys)
        let mut popped: Vec<usize> = Vec::new();
        while popped.len() < self.params.policy.round_arms {
            match self.pop_fresh() {
                Some(a) => popped.push(a),
                None => break,
            }
        }
        for &a in &popped {
            self.push_heap(a);
        }
        for &a in &popped {
            if self.states[a].exact {
                continue;
            }
            if arms.max_pulls(a).saturating_sub(self.states[a].pulls) >= t {
                pred.push(a);
            }
        }
        if pred.is_empty() { None } else { Some((pred, t)) }
    }

    /// Advance scheduling until the run either completes or needs a
    /// uniform batch pull executed by the caller.
    ///
    /// Everything that cannot be coalesced across queries — init-phase
    /// ragged pulls, arms within `round_pulls` of their MAX_PULLS cap, and
    /// exact evaluations — is resolved directly against `arms` here; only
    /// the uniform `round_pulls`-sized batches (the hot path) are staged
    /// for the caller. The rng/counter effects and pull sequencing are
    /// identical to what the pre-refactor monolithic loop produced.
    pub fn begin_round<A: ArmSet>(&mut self, arms: &mut A, rng: &mut Rng,
                                  counter: &mut Counter) -> RoundAction {
        assert!(self.pending.is_empty(),
                "begin_round called with a staged pull outstanding");
        if self.finished || self.best.len() >= self.params.k {
            self.finished = true;
            return RoundAction::Done;
        }
        let n = self.states.len();
        // ---- init pulls (batched across all arms) -----------------------
        if !self.init_done {
            self.init_done = true;
            self.t0 = Some(Instant::now());
            self.start_units = counter.get();
            let init = self.params.policy.init_pulls;
            if init > 0 {
                // per-arm cap: don't exceed max_pulls at init (a staged
                // pull uses a uniform t; arm sets with smaller caps are
                // pulled individually instead)
                let uniform_cap =
                    (0..n).map(|i| arms.max_pulls(i)).min().unwrap_or(1);
                if init <= uniform_cap {
                    self.pending = (0..n).collect();
                    self.pending_t = init;
                    self.init_heap_pending = true;
                    return RoundAction::Pull { t: init };
                }
                for a in 0..n {
                    let t = init.min(arms.max_pulls(a));
                    if t > 0 {
                        let (s, s2) = arms.pull(a, t, rng, counter);
                        self.record_samples(a, t, s, s2);
                    }
                }
            }
            for a in 0..n {
                self.push_heap(a);
            }
        }
        // ---- main rounds ------------------------------------------------
        // Rounds that need no engine batch (every selected arm was exact
        // or near its cap) are completed inline and the loop continues, so
        // callers only ever see Done or a staged Pull.
        loop {
            self.rounds += 1;
            // (1) emit as many separated arms as possible
            loop {
                let Some(top) = self.pop_fresh() else {
                    // heap exhausted — no live arms left
                    self.finished = true;
                    return RoundAction::Done;
                };
                let second_lcb = self.peek_fresh_lcb();
                if self.emit_condition(top, second_lcb) {
                    self.states[top].removed = true;
                    self.best.push((top, self.states[top].mean));
                    if self.best.len() == self.params.k {
                        self.finished = true;
                        return RoundAction::Done;
                    }
                } else {
                    // not separable yet: top goes back into play as the
                    // first selected arm of this round
                    self.selected.clear();
                    self.selected.push(top);
                    break;
                }
            }
            // (2) select up to round_arms-1 further arms by LCB
            while self.selected.len() < self.params.policy.round_arms {
                match self.pop_fresh() {
                    Some(a) => self.selected.push(a),
                    None => break,
                }
            }
            // (3) pull or exact-evaluate each selected arm: arms at their
            // cap are exact-evaluated, ragged (near-cap) arms are pulled
            // individually, and the remaining uniform batch is staged
            let mut batchable: Vec<usize> = Vec::new();
            for i in 0..self.selected.len() {
                let a = self.selected[i];
                if self.states[a].exact {
                    // exact arm got selected but could not be emitted —
                    // its competitor needs more pulls; nothing to do for
                    // this arm itself.
                    continue;
                }
                if self.states[a].pulls >= arms.max_pulls(a) {
                    let theta = arms.exact_mean(a, counter);
                    self.exact_evals += 1;
                    self.set_exact(a, theta);
                } else {
                    batchable.push(a);
                }
            }
            let t = self.params.policy.round_pulls;
            let mut uniform: Vec<usize> = Vec::new();
            if !batchable.is_empty() {
                if t == 1 || batchable.len() == 1 {
                    for &a in &batchable {
                        let tt = t.min(
                            arms.max_pulls(a) - self.states[a].pulls);
                        let (s, s2) = arms.pull(a, tt, rng, counter);
                        self.record_samples(a, tt, s, s2);
                    }
                } else {
                    // uniform t across the batch, capped by each arm's
                    // remaining budget — arms near their cap drop out of
                    // the batch and are pulled individually
                    for &a in &batchable {
                        let left = arms.max_pulls(a) - self.states[a].pulls;
                        if left >= t {
                            uniform.push(a);
                        } else {
                            let (s, s2) = arms.pull(a, left, rng, counter);
                            self.record_samples(a, left, s, s2);
                        }
                    }
                }
            }
            if uniform.is_empty() {
                // nothing to stage: requeue the round's arms and continue
                for i in 0..self.selected.len() {
                    let a = self.selected[i];
                    self.push_heap(a);
                }
                continue;
            }
            self.pending = uniform;
            self.pending_t = t;
            return RoundAction::Pull { t };
        }
    }

    /// Absorb the (Σx, Σx²) of the staged pull (one pair per arm of
    /// [`BmoUcb::pending_arms`], `pending` order) and requeue the round's
    /// arms. Must follow a `begin_round` that returned
    /// [`RoundAction::Pull`].
    pub fn end_round(&mut self, sums: &[f64], sqs: &[f64]) {
        assert_eq!(sums.len(), self.pending.len(),
                   "end_round: wrong result length");
        assert_eq!(sqs.len(), self.pending.len());
        let t = self.pending_t;
        let pending = std::mem::take(&mut self.pending);
        for ((&a, &s), &s2) in pending.iter().zip(sums).zip(sqs) {
            self.record_samples(a, t, s, s2);
        }
        if self.init_heap_pending {
            self.init_heap_pending = false;
            for a in 0..self.states.len() {
                self.push_heap(a);
            }
        } else {
            let selected = std::mem::take(&mut self.selected);
            for &a in &selected {
                self.push_heap(a);
            }
            self.selected = selected;
            self.selected.clear();
        }
    }

    /// Run to completion over `arms`. Charges `counter` one unit per
    /// sampled coordinate and `exact_cost(arm)` per exact evaluation
    /// (the [`crate::metrics`] accounting contract).
    pub fn run<A: ArmSet>(&mut self, arms: &mut A, rng: &mut Rng,
                          counter: &mut Counter) -> BanditResult {
        let mut sums: Vec<f64> = Vec::new();
        let mut sqs: Vec<f64> = Vec::new();
        loop {
            match self.begin_round(arms, rng, counter) {
                RoundAction::Done => return self.result(counter),
                RoundAction::Pull { t } => {
                    arms.pull_batch(&self.pending, t, rng, counter,
                                    &mut sums, &mut sqs);
                    self.end_round(&sums, &sqs);
                }
            }
        }
    }

    /// Snapshot the run's outcome (call after [`RoundAction::Done`]; `run`
    /// calls it for you). `counter` must be the same counter the run was
    /// charged to.
    pub fn result(&self, counter: &Counter) -> BanditResult {
        BanditResult {
            best: self.best.clone(),
            metrics: RunMetrics {
                dist_computations: counter.get() - self.start_units,
                rounds: self.rounds,
                exact_evals: self.exact_evals,
                elapsed: self.t0.map(|t| t.elapsed()).unwrap_or_default(),
            },
            pulls_per_arm: self.states.iter().map(|s| s.pulls).collect(),
            exact_per_arm: self.states.iter().map(|s| s.exact).collect(),
        }
    }
}

/// Convenience wrapper: run BMO UCB over an [`ArmSet`] with fresh state.
pub fn run_bmo_ucb<A: ArmSet>(arms: &mut A, params: BanditParams,
                              rng: &mut Rng, counter: &mut Counter)
                              -> BanditResult {
    let mut b = BmoUcb::new(arms, params);
    b.run(arms, rng, counter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::arms::{DenseArms, ScalarEngine};
    use crate::data::dense::Metric;
    use crate::data::synthetic;
    use crate::metrics::Counter;

    fn knn_ids(ds: &crate::data::DenseDataset, q: usize, k: usize)
               -> Vec<u32> {
        let mut c = Counter::new();
        let mut d: Vec<(f64, u32)> = (0..ds.n)
            .filter(|&i| i != q)
            .map(|i| (ds.dist(q, i, Metric::L2Sq, &mut c), i as u32))
            .collect();
        d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        d.truncate(k);
        d.into_iter().map(|(_, i)| i).collect()
    }

    fn run_once(n: usize, d: usize, k: usize, policy: PullPolicy,
                seed: u64) -> (Vec<u32>, Vec<u32>, u64) {
        let ds = synthetic::gaussian_means(n, d, 4.0, 1.0, seed);
        let truth = knn_ids(&ds, 0, k);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(n, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let params = BanditParams {
            k,
            delta: 0.01,
            sigma: SigmaMode::Empirical,
            epsilon: 0.0,
            policy,
            bias: 0.0,
        };
        let mut rng = Rng::new(seed + 1);
        let mut c = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut c);
        let got: Vec<u32> =
            res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect();
        (got, truth, res.metrics.dist_computations)
    }

    #[test]
    fn faithful_mode_finds_exact_nn() {
        for seed in 0..5 {
            let (got, truth, _) =
                run_once(50, 256, 1, PullPolicy::faithful(), seed);
            assert_eq!(got, truth, "seed {seed}");
        }
    }

    #[test]
    fn batched_mode_finds_exact_topk() {
        for seed in 0..5 {
            let (got, truth, _) =
                run_once(60, 512, 5, PullPolicy::batched(), seed);
            let gs: std::collections::HashSet<_> = got.iter().collect();
            let ts: std::collections::HashSet<_> = truth.iter().collect();
            assert_eq!(gs, ts, "seed {seed}: got {got:?} want {truth:?}");
        }
    }

    #[test]
    fn emission_order_is_increasing_theta() {
        let (got, truth, _) = run_once(40, 512, 5, PullPolicy::batched(), 9);
        // truth is sorted by distance; emission order should match
        assert_eq!(got, truth);
    }

    #[test]
    fn cost_never_exceeds_2nd_plus_overhead() {
        // "even if the algorithm fails it will not take more than 2nd
        //  coordinate-wise distance computations" (§III-A) — per query.
        let (_, _, units) = run_once(50, 128, 1, PullPolicy::faithful(), 3);
        assert!(units <= 2 * 50 * 128 + 50 * 32,
                "units {units} exceed 2nd cap");
    }

    #[test]
    fn beats_exact_computation_on_easy_instances() {
        // big d, well-separated arms (power-law gaps, alpha=3: most gaps
        // near 1) → far fewer than n·d pulls
        let n = 100;
        let d = 8192;
        let ds = synthetic::power_law_gaps(n, d, 3.0, 1.0, 5);
        let truth = knn_ids(&ds, 0, 1);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(n, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let params = BanditParams { k: 1, ..Default::default() };
        let mut rng = Rng::new(6);
        let mut c = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut c);
        assert_eq!(arms.arm_id(res.best[0].0), truth[0]);
        let exact_cost = (n as u64 - 1) * d as u64;
        assert!(c.get() < exact_cost / 2,
                "units {} not < half exact {exact_cost}", c.get());
    }

    #[test]
    fn fixed_sigma_mode_works() {
        let ds = synthetic::gaussian_means(30, 256, 4.0, 1.0, 11);
        let truth = knn_ids(&ds, 0, 1);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(30, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        // coordinate distances (g0-g1)² with θ≈4: scale ~ 2θ — generous σ
        let params = BanditParams {
            k: 1,
            delta: 0.01,
            sigma: SigmaMode::Fixed(10.0),
            epsilon: 0.0,
            policy: PullPolicy::batched(),
            bias: 0.0,
        };
        let mut rng = Rng::new(12);
        let mut c = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut c);
        assert_eq!(arms.arm_id(res.best[0].0), truth[0]);
    }

    #[test]
    fn k_equals_n_returns_everything() {
        let n = 10;
        let ds = synthetic::gaussian_iid(n + 1, 64, 13);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(n + 1, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let params = BanditParams { k: n, ..Default::default() };
        let mut rng = Rng::new(14);
        let mut c = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut c);
        assert_eq!(res.best.len(), n);
        let ids: std::collections::HashSet<_> =
            res.best.iter().map(|&(a, _)| a).collect();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn duplicate_points_terminate_via_exact_tiebreak() {
        // two identical nearest points: θ_(1) == θ_(2); algorithm must
        // still terminate (exact-eval collapses both CIs to a point)
        let d = 64;
        let mut data = Vec::new();
        // query at origin
        data.extend(std::iter::repeat(0.0f32).take(d));
        // two identical near points
        for _ in 0..2 {
            data.extend((0..d).map(|j| if j == 0 { 1.0f32 } else { 0.0 }));
        }
        // one far point
        data.extend((0..d).map(|_| 5.0f32));
        let ds = crate::data::DenseDataset::new(4, d, data);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(4, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let params = BanditParams { k: 2, ..Default::default() };
        let mut rng = Rng::new(15);
        let mut c = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut c);
        let got: std::collections::HashSet<u32> =
            res.best.iter().map(|&(a, _)| arms.arm_id(a)).collect();
        assert_eq!(got, [1u32, 2u32].into_iter().collect());
    }

    #[test]
    fn peek_fresh_lcb_skips_stale_and_removed_entries() {
        let ds = synthetic::gaussian_iid(4, 32, 21);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(4, Some(0));
        let arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let mut b = BmoUcb::new(&arms, BanditParams::default());
        // seed arms with distinct means and positive variance so LCBs are
        // finite and ordered: mean = arm index, sample variance = 16/15
        for a in 0..3usize {
            let m = a as f64;
            b.record_samples(a, 16, 16.0 * m, 16.0 * m * m + 16.0);
        }
        for a in 0..3 {
            b.push_heap(a);
        }
        // arm 0 has the lowest LCB; make its heap entry stale by bumping
        // its version, then push the fresh replacement
        let stale_len = b.heap.len();
        b.record_samples(0, 16, 0.0, 16.0);
        b.push_heap(0);
        assert_eq!(b.heap.len(), stale_len + 1);
        let want = b.lcb(0);
        assert_eq!(b.peek_fresh_lcb(), want, "fresh LCB of arm 0");
        // the stale arm-0 entry must have been dropped by the peek
        assert_eq!(b.heap.len(), stale_len, "stale entry popped");
        // removed arms are skipped even when their entry is fresh
        b.states[0].removed = true;
        let peeked = b.peek_fresh_lcb();
        assert_eq!(peeked, b.lcb(1).min(b.lcb(2)),
                   "removed arm 0 skipped");
        // exhausted heap peeks +infinity
        while b.pop_fresh().is_some() {}
        assert_eq!(b.peek_fresh_lcb(), f64::INFINITY);
    }

    #[test]
    fn emit_condition_tie_at_ucb_equals_second_lcb() {
        let ds = synthetic::gaussian_iid(3, 32, 22);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(3, Some(0));
        let arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let params = BanditParams {
            sigma: SigmaMode::Fixed(1.0),
            ..Default::default()
        };
        let mut b = BmoUcb::new(&arms, params);
        // non-exact arm with mean 0: ucb = ci exactly (Fixed sigma)
        b.record_samples(0, 16, 0.0, 0.0);
        let c = b.ci(0);
        assert!(c.is_finite() && c > 0.0);
        assert_eq!(b.ucb(0), c);
        // non-exact tie ucb == second_lcb: NOT separable (strict <) —
        // the intervals still touch, so emitting would be unsound
        assert!(!b.emit_condition(0, c), "non-exact tie must not emit");
        // strictly below: emits
        assert!(b.emit_condition(0, c + 1e-9));
        // exact arm: interval is a point, so a tie means the competitor
        // cannot be strictly better — emitting is correct (the paper's
        // θ_(k)=θ_(k+1) remark)
        b.set_exact(0, 0.5);
        assert_eq!(b.ucb(0), 0.5);
        assert!(b.emit_condition(0, 0.5), "exact tie must emit");
        assert!(!b.emit_condition(0, 0.5 - 1e-9),
                "exact arm above second LCB must not emit");
    }

    #[test]
    fn predict_next_pull_does_not_perturb_the_run() {
        // Driving a run with predict_next_pull called after every staged
        // pull must produce bitwise-identical results and pull counts to
        // a run that never predicts.
        fn drive(seed: u64, predict: bool)
                 -> (Vec<(usize, f64)>, Vec<u64>, u64) {
            let ds = synthetic::gaussian_means(40, 256, 4.0, 1.0, seed);
            let mut engine = ScalarEngine;
            let query = ds.row_vec(0);
            let rows = DenseArms::<ScalarEngine>::candidates(40, Some(0));
            let mut arms = DenseArms::new(&ds, &query, &rows, Metric::L2Sq,
                                          &mut engine);
            let params = BanditParams {
                k: 3,
                policy: PullPolicy {
                    init_pulls: 16,
                    round_arms: 8,
                    round_pulls: 32,
                },
                ..Default::default()
            };
            let mut b = BmoUcb::new(&arms, params);
            let mut rng = Rng::new(seed + 100);
            let mut c = Counter::new();
            let mut sums = Vec::new();
            let mut sqs = Vec::new();
            let mut predictions = 0u64;
            loop {
                match b.begin_round(&mut arms, &mut rng, &mut c) {
                    RoundAction::Done => break,
                    RoundAction::Pull { t } => {
                        if predict {
                            if let Some((pred, pt)) =
                                b.predict_next_pull(&arms)
                            {
                                predictions += 1;
                                assert_eq!(pt, 32);
                                // every pending arm with headroom is in
                                // the predicted superset
                                for &a in b.pending_arms() {
                                    if arms.max_pulls(a)
                                        >= b.states[a].pulls + 2 * pt
                                    {
                                        assert!(pred.contains(&a));
                                    }
                                }
                                // predicted arms all have cap headroom
                                for &a in &pred {
                                    assert!(!b.states[a].exact);
                                    assert!(b.states[a].pulls + pt
                                            <= arms.max_pulls(a));
                                }
                            }
                        }
                        arms.pull_batch(b.pending_arms(), t, &mut rng,
                                        &mut c, &mut sums, &mut sqs);
                        b.end_round(&sums, &sqs);
                    }
                }
            }
            if predict {
                assert!(predictions > 0, "no predictions exercised");
            }
            let res = b.result(&c);
            (res.best, res.pulls_per_arm, c.get())
        }
        for seed in 0..3 {
            assert_eq!(drive(seed, true), drive(seed, false),
                       "seed {seed}");
        }
    }

    #[test]
    fn pac_epsilon_emits_near_optimal_arm() {
        // many arms within ε of the best: PAC mode must terminate fast and
        // return an ε-best arm (Theorem 2)
        let ds = synthetic::power_law_gaps(200, 1024, 0.5, 1.0, 16);
        let mut c = Counter::new();
        let theta_best = (1..200)
            .map(|i| ds.dist(0, i, Metric::L2Sq, &mut c) / 1024.0)
            .fold(f64::INFINITY, f64::min);
        let mut engine = ScalarEngine;
        let query = ds.row_vec(0);
        let rows = DenseArms::<ScalarEngine>::candidates(200, Some(0));
        let mut arms =
            DenseArms::new(&ds, &query, &rows, Metric::L2Sq, &mut engine);
        let eps = 0.5;
        let params = BanditParams {
            k: 1,
            epsilon: eps,
            ..Default::default()
        };
        let mut rng = Rng::new(17);
        let mut cc = Counter::new();
        let res = run_bmo_ucb(&mut arms, params, &mut rng, &mut cc);
        let winner = arms.arm_id(res.best[0].0);
        let theta_win =
            ds.dist(0, winner as usize, Metric::L2Sq, &mut c) / 1024.0;
        assert!(theta_win <= theta_best + eps,
                "winner θ {theta_win} > best {theta_best} + ε {eps}");
    }
}
