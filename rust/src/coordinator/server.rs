//! k-NN query server: TCP, line-delimited JSON, with a **fixed compute
//! worker pool** fed by a shared queue.
//!
//! Architecture (the L3 request path — Python never runs here):
//!
//! * One accept thread hands each connection to a lightweight I/O thread
//!   that does framing, parsing and validation only. `ping` / `stats` /
//!   `shutdown` are answered inline; `knn` requests are enqueued on the
//!   shared queue and the I/O thread blocks until its response is ready —
//!   which keeps the line protocol's request/response ordering per
//!   connection while letting *different* connections' queries coalesce.
//! * `n_workers` compute workers drain up to `batch_size` queued queries
//!   per pass and resolve the whole wave with one
//!   `coordinator::knn::knn_batch_dense` call: every in-flight query's
//!   bandit advances in lockstep and their per-round coordinate pulls are
//!   coalesced into a single `PullEngine::pull_batch` sweep of the
//!   dataset, so under concurrent load each data block is read once per
//!   round instead of once per query. With `batch_wait_us > 0`
//!   (`[server] batch_wait_us` / `--batch-wait-us`) a worker that found
//!   a non-full batch lingers that long for more arrivals — trading a
//!   bounded p50 bump for fuller batches under light load; the realized
//!   batch sizes are observable via `stats` (`mean_batch`/`max_batch`).
//! * Each worker owns its RNG and engine. With `--remote`, all workers
//!   share **one** multiplexed `runtime::remote::RingClient` (each
//!   wraps it in its own cheap `RemoteEngine`), so independent batches
//!   genuinely overlap on the one-connection-per-shard wire instead of
//!   opening W×S sockets. Counters and per-batch latency
//!   (`metrics::BatchStats`) merge into server totals for `stats`.
//!
//! **Deadline budgets and admission control.** With `deadline_ms > 0`
//! (`[server] deadline_ms` / `--deadline-ms`, overridable per request
//! by a `deadline_ms` JSON field) every query carries an absolute
//! budget from the moment it is parsed: queue wait, lockstep rounds and
//! remote wave waits all charge against it, and on expiry the query is
//! answered with a structured `deadline_exceeded` error (or, on a
//! degraded ring, a coverage-annotated partial answer) instead of
//! stalling a worker for a full I/O timeout. With `max_queue > 0`
//! (`[server] max_queue` / `--max-queue`) a full shared queue sheds new
//! queries immediately with an `overload` error carrying a
//! `retry_after_ms` hint. Both outcomes are counted
//! (`metrics::BatchStats`) and surfaced via `stats`.
//!
//! Protocol (one JSON object per line):
//!   request:  {"op":"knn",   "query":[f32...], "k":5}
//!             {"op":"knn",   "query":[...], "k":5, "deadline_ms":20}
//!             {"op":"stats"}
//!             {"op":"ping"}
//!             {"op":"shutdown"}
//!   response: {"ok":true, "ids":[...], "dists":[...], "units":u}
//!             (degraded remote rings add "coverage" (fraction),
//!             "rows_live" and "rows_total" when a partial answer was
//!             computed over the surviving shards only)
//!             {"ok":true, "queries":q, "units":u, "p50_us":_, "p99_us":_,
//!              "batches":b, "mean_batch":_, "max_batch":_,
//!              "batch_p50_us":_, "batch_p99_us":_, "workers":w,
//!              "shed":_, "deadline_exceeded":_, "speculated":_,
//!              "spec_confirmed":_, "spec_discarded":_,
//!              "routes":{"op:knn":{"count":_, "mean_us":_, "p50_us":_,
//!              "p99_us":_}, ...}}
//!             {"ok":false, "error":"..."}
//!             {"ok":false, "error":"...", "kind":"deadline_exceeded"}
//!             {"ok":false, "error":"...", "kind":"overload",
//!              "retry_after_ms":_}
//!   admin:    {"op":"epoch-bump"} → {"ok":true, "epoch":e}
//!             {"op":"reshard", "to":["host:port",...], "epoch":e?}
//!               → {"ok":true, "placement_epoch":_, "epoch":_,
//!                  "shards":_}
//!
//! **HTTP front door.** With `http_port` set (`[server] http_port` /
//! `--http-port`) the same validated request path is additionally served
//! over HTTP/1.1 by [`crate::coordinator::http`]: `POST /knn` carries
//! the `knn` request body (same fields, same validation, same deadline
//! stamping and admission), `GET /metrics` returns the `stats` body,
//! and overload/deadline answers map to real `429` (with `Retry-After`)
//! and `504` status codes.
//!
//! **Result cache.** With `cache_entries > 0` (`[server] cache_entries`
//! / `--cache-entries`) an LRU answer cache
//! ([`crate::coordinator::cache`]) sits in front of the queue, keyed on
//! (query bits, k, eps/delta mode, dataset fingerprint, placement
//! epoch). Compute is seeded from the same query-content hash
//! ([`crate::coordinator::knn::knn_batch_dense_seeded`]), which makes
//! every answer bitwise-reproducible — so a hit replays exactly the
//! bytes a fresh compute would produce, without consuming a queue slot
//! or a bandit pull. Only full-coverage successes are cached; the
//! `epoch-bump` op (or `POST /admin/epoch-bump`) invalidates every
//! prior entry by changing the key.
//!
//! **Elastic placement.** On a remote configuration the `reshard` op
//! (or `POST /admin/reshard`) rebalances the ring under live traffic:
//! it streams every shard's rows to a new placement of staging servers
//! ([`crate::runtime::remote::reshard_to`] — each transfer is
//! fingerprint-verified before the new server starts serving), opens a
//! fresh [`RingClient`] with the new placement epoch pinned, and flips
//! placement + shared ring client + result-cache epoch as one unit.
//! Workers finish the batch in flight on the old client (the drain)
//! and pick up the new one at their next batch boundary; the cache
//! epoch bump happens automatically, so an answer computed on the old
//! placement can never be replayed after the flip. Any failure before
//! the flip leaves the old placement serving, untouched. `stats` /
//! `GET /metrics` surface the current `placement_epoch` plus a
//! per-endpoint `ring` health array for observability.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::EngineKind;
use crate::coordinator::arms::PullEngine;
use crate::coordinator::bandit::BanditParams;
use crate::coordinator::cache::{hash_query, CacheKey, ResultCache};
use crate::coordinator::knn::{knn_batch_dense_seeded_opts, BatchOptions};
use crate::runtime::wire::{dataset_fingerprint, is_deadline_error};
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::{BatchStats, Counter, LatencyStats};
use crate::runtime::build_host_engine;
use crate::runtime::placement::{PlacementMap, RetryPolicy};
use crate::runtime::remote::{endpoint_stats, reshard_to, EndpointStats,
                             RemoteEngine, RemoteOptions, RingClient};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub metric: Metric,
    pub params: BanditParams,
    /// compute worker threads draining the shared query queue
    pub n_workers: usize,
    /// max queued queries coalesced into one worker pass
    pub batch_size: usize,
    /// use the optimized native engine (true) or the scalar reference
    pub native_engine: bool,
    /// row shards each worker's engine fans pull waves across (1 =
    /// single-threaded per worker; results are identical either way)
    pub shards: usize,
    /// shard-server endpoints: when non-empty each worker's engine is a
    /// `runtime::remote::RemoteEngine` over this ring (`--remote`; each
    /// entry may be a `|`-separated replica list), so this box becomes
    /// the coordinator of a multi-machine deployment. Workers
    /// (re)connect lazily, fail sub-waves over between a shard's
    /// replicas, and survive ring outages by answering error responses
    /// until the ring is reachable again.
    pub remote: Vec<String>,
    /// degraded mode (`--degraded`, remote rings only): when a shard has
    /// no live replica, `knn` responses carry exact answers over the
    /// surviving rows plus `coverage`/`rows_live`/`rows_total` fields
    /// instead of errors.
    pub degraded: bool,
    /// adaptive wait-a-little batching (`[server] batch_wait_us` /
    /// `--batch-wait-us`): a worker that drained a non-full batch waits
    /// up to this many microseconds for more queries to arrive before
    /// computing, trading a bounded latency bump for fuller coalesced
    /// batches under light load. 0 (the default) keeps the
    /// drain-immediately behavior.
    pub batch_wait_us: u64,
    /// row-kernel tier every worker's native engine dispatches
    /// (`[engine] kernel` / `--kernel`); forcing an unavailable tier
    /// fails at server startup. Local engines only (with `remote`, the
    /// shard servers own the kernels).
    pub kernel: crate::runtime::kernels::KernelChoice,
    /// opt-in int8 sampling tier for every worker's native engine
    /// (`[engine] quantized` / `--quantized`); local engines only.
    pub quantized: bool,
    /// default per-query deadline budget in milliseconds (`[server]
    /// deadline_ms` / `--deadline-ms`): each query must be answered
    /// within this long of arriving — queue wait included — or it gets
    /// a structured `deadline_exceeded` error. A request's own
    /// `deadline_ms` JSON field overrides it per query. 0 (the
    /// default) disables the budget.
    pub deadline_ms: u64,
    /// admission bound on the shared queue (`[server] max_queue` /
    /// `--max-queue`): a query arriving while this many are already
    /// queued is shed immediately with an `overload` error carrying a
    /// `retry_after_ms` hint, instead of growing the queue (and every
    /// queued query's latency) without bound. 0 (the default) keeps
    /// the queue unbounded.
    pub max_queue: usize,
    /// per-connection I/O timeout in milliseconds for the workers'
    /// shared ring client (`[engine] io_timeout_ms` /
    /// `--io-timeout-ms`); remote configurations only. Must be > 0.
    pub io_timeout_ms: u64,
    /// speculative cross-round wave pipelining (`[engine] speculate` /
    /// `--speculate`): workers overlap each bandit round's retirement
    /// with the next round's predicted pull wave on pipelined (remote)
    /// engines, abandoning mispredicted waves without consuming
    /// failover attempts or deadline budget. Answers stay
    /// bitwise-identical; speculated/confirmed/discarded pull counts
    /// surface via `stats` / `GET /metrics`. Off by default; inert on
    /// local (blocking) engines.
    pub speculate: bool,
    /// placement epoch to pin the initial ring connect to (`[engine]
    /// epoch` / `--epoch`, remote configurations only): nonzero makes
    /// the workers refuse endpoints carrying any other epoch — for
    /// restarting a coordinator whose ring was already resharded to a
    /// known epoch. 0 (the default) adopts whatever single epoch the
    /// ring agrees on; a live `reshard` op pins the new epoch itself.
    pub epoch: u64,
    /// HTTP front-door port (`[server] http_port` / `--http-port`):
    /// when set, an HTTP/1.1 listener on the same host serves `POST
    /// /knn`, `GET /metrics`, `GET /healthz` and `POST
    /// /admin/epoch-bump` through the same validation, deadline and
    /// admission path as the line protocol. `Some(0)` binds an
    /// ephemeral port (tests); `None` (the default) disables HTTP.
    pub http_port: Option<u16>,
    /// LRU result-cache capacity in entries (`[server] cache_entries`
    /// / `--cache-entries`): answers to full-coverage successful
    /// queries are replayed byte-identically for repeat requests with
    /// the same (query, k) under the same dataset fingerprint and
    /// placement epoch. 0 (the default) disables the cache.
    pub cache_entries: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            metric: Metric::L2Sq,
            params: BanditParams::default(),
            n_workers: 4,
            batch_size: 8,
            native_engine: true,
            shards: 1,
            remote: Vec::new(),
            degraded: false,
            batch_wait_us: 0,
            kernel: crate::runtime::kernels::KernelChoice::Auto,
            quantized: false,
            deadline_ms: 0,
            max_queue: 0,
            io_timeout_ms: 60_000,
            speculate: false,
            epoch: 0,
            http_port: None,
            cache_entries: 0,
        }
    }
}

/// A validated `knn` request waiting on the shared queue. The submitting
/// I/O thread parks on `done` until a worker publishes the response.
struct Job {
    query: Vec<f32>,
    k: usize,
    /// rng seed for the compute stream — `cache::hash_query(query, k)`,
    /// so identical requests get bitwise-identical answers no matter
    /// which worker or batch serves them
    seed: u64,
    /// absolute answer-by deadline, stamped at request arrival (server
    /// default or the request's own `deadline_ms`); `None` = unbounded
    deadline: Option<Instant>,
    done: Arc<(Mutex<Option<Json>>, Condvar)>,
}

/// The worker-facing view of the ring: which endpoints to connect to
/// and which placement epoch to demand at handshake. A completed
/// `reshard` swaps both as one unit; `ServerConfig::remote` only seeds
/// the initial value.
struct Placement {
    /// endpoint specs, one per shard (replicas `|`-separated)
    endpoints: Vec<String>,
    /// epoch pinned at connect time — `None` until the first reshard
    /// (a fresh ring adopts whatever single epoch its endpoints agree
    /// on, which is 0 for never-resharded servers)
    epoch: Option<u64>,
}

/// Everything the accept/IO/worker/HTTP threads share. `pub(crate)` so
/// the HTTP front door ([`crate::coordinator::http`]) can route into
/// the same request path.
pub(crate) struct Shared {
    data: DenseDataset,
    config: ServerConfig,
    queue: Mutex<VecDeque<Job>>,
    queue_cv: Condvar,
    total_units: AtomicU64,
    total_queries: AtomicU64,
    /// per-query latency, enqueue → response ready (includes queue wait)
    latencies: Mutex<LatencyStats>,
    /// per-route/per-op latency windows, keyed by route label ("POST
    /// /knn", "GET /metrics", ... for the HTTP front door; "op:knn",
    /// "op:stats", ... for the line protocol). Each value is its own
    /// [`LatencyStats`] ring window, so a slow admin op can never skew
    /// the serving percentiles and vice versa; surfaced as the
    /// `routes` object of `stats` / `GET /metrics`.
    route_lat: Mutex<BTreeMap<&'static str, LatencyStats>>,
    /// per-worker-pass batch accounting
    batches: Mutex<BatchStats>,
    /// the one multiplexed ring client every worker's `RemoteEngine`
    /// shares when `config.remote` is set — connected lazily (the ring
    /// may be down at startup) and dropped when a compute panic makes a
    /// worker suspect it, so the next batch reconnects from scratch
    ring: Mutex<Option<Arc<RingClient>>>,
    /// current ring placement (endpoints + pinned epoch), swapped
    /// atomically by a completed `reshard` op — workers parse this, not
    /// `config.remote`, when they (re)connect
    placement: Mutex<Placement>,
    /// `wire::dataset_fingerprint` of the served dataset, computed once
    /// at startup; part of every cache key (0 when the cache is off)
    fingerprint: u64,
    /// placement epoch: part of every cache key, so bumping it
    /// (`epoch-bump` / `POST /admin/epoch-bump`) orphans all prior
    /// cache entries without touching them
    epoch: AtomicU64,
    /// LRU answer cache (`None` when `cache_entries == 0`)
    cache: Option<Mutex<ResultCache>>,
    pub(crate) shutdown: AtomicBool,
}

/// Build a worker's engine. Local configurations build their own
/// engine; remote ones connect (or reuse) the server-wide shared
/// [`RingClient`] and wrap it in a per-worker [`RemoteEngine`], so all
/// workers' waves multiplex onto one connection set.
fn build_worker_engine(shared: &Shared, kind: EngineKind,
                       ring_in_use: &mut Option<Arc<RingClient>>)
                       -> Result<Box<dyn PullEngine + Send>, String> {
    if shared.config.remote.is_empty() {
        return build_host_engine(kind, shared.config.shards, &[],
                                 shared.config.degraded,
                                 shared.config.kernel,
                                 shared.config.quantized, false, None);
    }
    let client = shared.ring.lock().unwrap().clone();
    let client = match client {
        Some(c) => c,
        None => {
            // connect WITHOUT holding the shared slot's mutex: during a
            // ring outage every worker must fail (and answer "engine
            // unavailable") after ~one connect-timeout window in
            // parallel, not stacked behind one another's dial attempts.
            // The *current* placement is what we dial — after a reshard
            // that is the new ring, with its epoch pinned so an
            // old-placement endpoint can never rejoin.
            let (specs, expect) = {
                let p = shared.placement.lock().unwrap();
                (p.endpoints.clone(), p.epoch)
            };
            let map = PlacementMap::parse(&specs)?;
            let opts = RemoteOptions {
                degraded: shared.config.degraded,
                timeout: Some(Duration::from_millis(
                    shared.config.io_timeout_ms.max(1))),
                expect_epoch: expect,
                ..RemoteOptions::default()
            };
            let fresh = Arc::new(RingClient::connect_opts(&map, opts)?);
            let mut ring = shared.ring.lock().unwrap();
            match &*ring {
                // another worker won the connect race: share its client
                // (ours tears down on drop)
                Some(c) => c.clone(),
                None => {
                    *ring = Some(fresh.clone());
                    fresh
                }
            }
        }
    };
    *ring_in_use = Some(client.clone());
    Ok(Box::new(RemoteEngine::from_client(client)))
}

/// After a compute panic on a remote configuration, drop the shared
/// ring client so the rebuild reconnects from scratch — but only if it
/// is still the client this worker was computing with (another worker
/// may have already reconnected a healthy one; discarding that would
/// force a needless extra ring connect).
fn invalidate_ring(shared: &Shared,
                   ring_in_use: &Option<Arc<RingClient>>) {
    if shared.config.remote.is_empty() {
        return;
    }
    let mut ring = shared.ring.lock().unwrap();
    let stale = match (&*ring, ring_in_use) {
        (Some(cur), Some(mine)) => Arc::ptr_eq(cur, mine),
        (Some(_), None) => false,
        (None, _) => false,
    };
    if stale {
        *ring = None;
    }
}

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// bound address of the HTTP front door (`None` when `http_port`
    /// was not configured)
    pub http_addr: Option<std::net::SocketAddr>,
    shared: Arc<Shared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    http_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving `data` in background threads.
    pub fn start(data: DenseDataset, config: ServerConfig)
                 -> std::io::Result<Server> {
        // resolve the forced kernel tier now: a tier this host lacks
        // must fail server startup, not every worker batch one "engine
        // unavailable" reply at a time
        if config.remote.is_empty() && config.native_engine {
            crate::runtime::kernels::resolve(config.kernel).map_err(
                |e| std::io::Error::new(std::io::ErrorKind::InvalidInput,
                                        e))?;
        }
        let listener = TcpListener::bind(&config.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        // the HTTP front door binds the same host as the line protocol
        let http_listener = match config.http_port {
            None => None,
            Some(port) => {
                let l = TcpListener::bind((addr.ip(), port))?;
                l.set_nonblocking(true)?;
                Some(l)
            }
        };
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };
        let n_workers = config.n_workers.max(1);
        // fingerprint once at startup — it keys every cache entry, and
        // ring-stats surfaces the same value for cross-checking
        let fingerprint = if config.cache_entries > 0 {
            dataset_fingerprint(data.n, 0, &data)
        } else {
            0
        };
        let cache = (config.cache_entries > 0)
            .then(|| Mutex::new(ResultCache::new(config.cache_entries)));
        let placement = Mutex::new(Placement {
            endpoints: config.remote.clone(),
            epoch: (config.epoch > 0).then_some(config.epoch),
        });
        let shared = Arc::new(Shared {
            data,
            config,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            total_units: AtomicU64::new(0),
            total_queries: AtomicU64::new(0),
            latencies: Mutex::new(LatencyStats::default()),
            route_lat: Mutex::new(BTreeMap::new()),
            batches: Mutex::new(BatchStats::default()),
            ring: Mutex::new(None),
            placement,
            fingerprint,
            epoch: AtomicU64::new(0),
            cache,
            shutdown: AtomicBool::new(false),
        });
        let worker_handles = (0..n_workers)
            .map(|_| {
                let s = shared.clone();
                std::thread::spawn(move || worker_loop(s))
            })
            .collect();
        let accept_shared = shared.clone();
        let handle = std::thread::spawn(move || {
            accept_loop(listener, accept_shared);
        });
        let http_handle = http_listener.map(|l| {
            let s = shared.clone();
            std::thread::spawn(move || {
                crate::coordinator::http::accept_loop(l, s);
            })
        });
        Ok(Server {
            addr,
            http_addr,
            shared,
            accept_handle: Some(handle),
            http_handle,
            worker_handles,
        })
    }

    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.http_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn total_queries(&self) -> u64 {
        self.shared.total_queries.load(Ordering::Relaxed)
    }

    pub fn total_units(&self) -> u64 {
        self.shared.total_units.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Compute worker: drain up to `batch_size` queued queries, resolve the
/// wave with one batched multi-query bandit pass, publish responses.
///
/// Each query computes under its own content-derived rng seed
/// (`Job::seed`), so answers are bitwise-identical across workers,
/// batch compositions and restarts — the property the result cache's
/// byte-identity contract rests on.
fn worker_loop(shared: Arc<Shared>) {
    let kind = if shared.config.native_engine {
        EngineKind::Native
    } else {
        EngineKind::Scalar
    };
    // The engine is built lazily and rebuilt after a compute panic.
    // Local engines build infallibly, but a remote ring may be down —
    // then the worker answers error responses (never hangs waiters) and
    // retries the connection on the next batch.
    let mut engine: Option<Box<dyn PullEngine + Send>> = None;
    // the shared RingClient this worker's current engine wraps (remote
    // configs only) — lets the panic path invalidate the shared client
    // without clobbering a fresh one another worker reconnected
    let mut ring_in_use: Option<Arc<RingClient>> = None;
    loop {
        let jobs: Vec<Job> = {
            let batch_size = shared.config.batch_size.max(1);
            let mut q = shared.queue.lock().unwrap();
            loop {
                while q.is_empty() {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let (guard, _) = shared
                        .queue_cv
                        .wait_timeout(q, Duration::from_millis(50))
                        .unwrap();
                    q = guard;
                }
                // adaptive wait-a-little batching: the queue is
                // non-empty but not full — linger briefly for more
                // arrivals so light load still coalesces, instead of
                // computing batches of one
                if shared.config.batch_wait_us > 0 && q.len() < batch_size
                {
                    let deadline = Instant::now()
                        + Duration::from_micros(
                            shared.config.batch_wait_us);
                    while q.len() < batch_size
                        && !shared.shutdown.load(Ordering::SeqCst)
                    {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        let (guard, _) = shared
                            .queue_cv
                            .wait_timeout(q, deadline - now)
                            .unwrap();
                        q = guard;
                    }
                }
                // the lock was released during waits: another worker
                // may have drained the queue under us — go wait again
                let take = q.len().min(batch_size);
                if take > 0 {
                    break q.drain(..take).collect();
                }
            }
        };
        // a completed reshard swapped the shared ring client: a worker
        // holding an engine over the *old* client drains naturally (the
        // wave it already started finished before this batch was
        // drained) and notices here, at the batch boundary — dropping
        // the stale engine so the rebuild below wraps the new
        // placement. The old client's connections close when its last
        // worker lets go of the Arc.
        if !shared.config.remote.is_empty() && engine.is_some() {
            let stale = match (&*shared.ring.lock().unwrap(),
                               &ring_in_use) {
                (Some(cur), Some(mine)) => !Arc::ptr_eq(cur, mine),
                (Some(_), None) => true,
                // shared slot empty (a panic path invalidated it):
                // keep this engine — it may still be healthy, and the
                // panic path rebuilds its own
                (None, _) => false,
            };
            if stale {
                engine = None;
                ring_in_use = None;
            }
        }
        let t0 = Instant::now();
        let mut responses: Vec<Option<Json>> =
            (0..jobs.len()).map(|_| None).collect();
        let mut batch_units = 0u64;
        // jobs whose budget ran out while queued are answered without
        // compute — spending rounds on a query nobody can use anymore
        // only steals budget from the live ones sharing its batch
        let mut expired_in_queue = 0u64;
        for (i, job) in jobs.iter().enumerate() {
            if job.deadline.is_some_and(|dl| Instant::now() >= dl) {
                responses[i] = Some(deadline_json("queue wait"));
                expired_in_queue += 1;
            }
        }
        if expired_in_queue > 0 {
            shared
                .batches
                .lock()
                .unwrap()
                .record_deadline_exceeded(expired_in_queue);
        }
        if engine.is_none() {
            match build_worker_engine(&shared, kind, &mut ring_in_use) {
                Ok(e) => engine = Some(e),
                Err(e) => {
                    let msg = format!("engine unavailable: {e}");
                    for r in responses.iter_mut().filter(|r| r.is_none())
                    {
                        *r = Some(err_json(&msg));
                    }
                }
            }
        }
        let mut poisoned = false;
        if let Some(eng) = engine.as_mut() {
            // group by k — the driver runs one k per wave; real traffic
            // is nearly always uniform in k, so this rarely splits a
            // batch
            let mut by_k: std::collections::BTreeMap<usize, Vec<usize>> =
                std::collections::BTreeMap::new();
            for (i, job) in jobs.iter().enumerate() {
                // skip jobs already answered (expired in queue)
                if responses[i].is_none() {
                    by_k.entry(job.k).or_default().push(i);
                }
            }
            'groups: for (k, idxs) in by_k {
                let queries: Vec<&[f32]> = idxs
                    .iter()
                    .map(|&i| jobs[i].query.as_slice())
                    .collect();
                let seeds: Vec<u64> =
                    idxs.iter().map(|&i| jobs[i].seed).collect();
                // the group computes in lockstep, so it must answer by
                // its *tightest* member's deadline — the budget the
                // whole wave runs under
                let deadline = idxs
                    .iter()
                    .filter_map(|&i| jobs[i].deadline)
                    .min();
                let mut params = shared.config.params.clone();
                params.k = k;
                let mut counter = Counter::new();
                // a panic in the compute path (including a remote shard
                // dying mid-wave) must not kill this shared worker: the
                // drained jobs' waiters would hang forever and the pool
                // would be permanently down a thread — catch it, answer
                // the affected queries with an error, and rebuild the
                // engine (its internals may be poisoned mid-wave; a
                // remote engine reconnects to the ring)
                let opts = BatchOptions {
                    deadline,
                    speculate: shared.config.speculate,
                };
                let outcome = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        knn_batch_dense_seeded_opts(
                            &shared.data, &queries, shared.config.metric,
                            &params, eng, &seeds, &mut counter, opts)
                    }));
                let results = match outcome {
                    Ok((results, spec)) => {
                        if spec.speculated > 0 {
                            shared.batches.lock().unwrap()
                                .record_speculation(spec.speculated,
                                                    spec.confirmed,
                                                    spec.discarded);
                        }
                        results
                    }
                    Err(payload) => {
                        // a deadline-budget expiry travels the same
                        // panic channel as a real crash but means the
                        // opposite: the machinery worked, the budget
                        // ran out. Answer a structured error and keep
                        // the engine — the ring client killed exactly
                        // the connection it stopped waiting on, and
                        // the next batch's set_deadline clears any
                        // abandoned waves.
                        if panic_msg(&payload)
                            .is_some_and(is_deadline_error)
                        {
                            shared
                                .batches
                                .lock()
                                .unwrap()
                                .record_deadline_exceeded(
                                    idxs.len() as u64);
                            for &i in &idxs {
                                responses[i] =
                                    Some(deadline_json("compute"));
                            }
                            continue;
                        }
                        for &i in &idxs {
                            responses[i] =
                                Some(err_json("internal error: compute \
                                               panicked"));
                        }
                        // a remote compute panic means the ring (or the
                        // shared client's view of it) is suspect: drop
                        // the shared client so the rebuild reconnects
                        // from scratch — while the ring is down that
                        // rebuild fails and the answers below say
                        // "engine unavailable", exactly like a local
                        // engine that cannot be built
                        invalidate_ring(&shared, &ring_in_use);
                        match build_worker_engine(&shared, kind,
                                                  &mut ring_in_use) {
                            Ok(fresh) => *eng = fresh,
                            Err(e) => {
                                // ring unreachable: answer the rest of
                                // this batch, drop the engine, retry on
                                // the next batch
                                let msg =
                                    format!("engine unavailable: {e}");
                                for r in responses
                                    .iter_mut()
                                    .filter(|r| r.is_none())
                                {
                                    *r = Some(err_json(&msg));
                                }
                                poisoned = true;
                                break 'groups;
                            }
                        }
                        continue;
                    }
                };
                for (&i, res) in idxs.iter().zip(&results) {
                    let units = res.metrics.dist_computations;
                    batch_units += units;
                    let mut fields = vec![
                        ("ok", Json::Bool(true)),
                        ("ids",
                         Json::usize_array(
                             &res.ids.iter().map(|&x| x as usize)
                                 .collect::<Vec<_>>())),
                        ("dists",
                         Json::f32_array(
                             &res.dists.iter().map(|&d| d as f32)
                                 .collect::<Vec<_>>())),
                        ("units", Json::Num(units as f64)),
                    ];
                    // degraded (partial-ring) answers carry an explicit
                    // coverage annotation; full answers stay unchanged
                    if let Some(cov) = &res.coverage {
                        fields.push(("coverage",
                                     Json::Num(cov.fraction())));
                        fields.push(("rows_live",
                                     Json::Num(cov.rows_live() as f64)));
                        fields.push(("rows_total",
                                     Json::Num(cov.rows_total as f64)));
                    }
                    responses[i] = Some(Json::obj(fields));
                }
            }
        }
        if poisoned {
            engine = None;
        }
        let elapsed = t0.elapsed();
        shared.total_units.fetch_add(batch_units, Ordering::Relaxed);
        shared
            .total_queries
            .fetch_add(jobs.len() as u64, Ordering::Relaxed);
        shared.batches.lock().unwrap().record(jobs.len(), elapsed);
        for (job, resp) in jobs.into_iter().zip(responses) {
            let (lock, cv) = &*job.done;
            *lock.lock().unwrap() =
                Some(resp.unwrap_or_else(|| err_json("internal error")));
            cv.notify_all();
        }
    }
}

/// Enqueue a validated knn job and block until a worker answers (or the
/// server shuts down under us). With `max_queue > 0`, a full queue sheds
/// the query right here — before it consumes a queue slot or a waiter —
/// with an `overload` answer.
fn submit_and_wait(shared: &Shared, query: Vec<f32>, k: usize, seed: u64,
                   deadline: Option<Instant>) -> Json {
    let done = Arc::new((Mutex::new(None), Condvar::new()));
    {
        let mut q = shared.queue.lock().unwrap();
        let cap = shared.config.max_queue;
        if cap > 0 && q.len() >= cap {
            drop(q);
            shared.batches.lock().unwrap().record_shed(1);
            return overload_json(shared);
        }
        q.push_back(Job { query, k, seed, deadline, done: done.clone() });
    }
    shared.queue_cv.notify_one();
    let (lock, cv) = &*done;
    let mut guard = lock.lock().unwrap();
    loop {
        if let Some(resp) = guard.take() {
            return resp;
        }
        let (g, timeout) = cv
            .wait_timeout(guard, Duration::from_millis(100))
            .unwrap();
        guard = g;
        if guard.is_none() && timeout.timed_out()
            && shared.shutdown.load(Ordering::SeqCst)
        {
            // grace period for the drain, then give up
            let (g2, t2) = cv
                .wait_timeout(guard, Duration::from_millis(500))
                .unwrap();
            guard = g2;
            if let Some(resp) = guard.take() {
                return resp;
            }
            if t2.timed_out() {
                return err_json("server shutting down");
            }
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut handles = Vec::new();
    // idle-poll backoff: a quiet listener decays from 5ms to 50ms polls
    // (shutdown latency stays bounded by the cap) instead of burning a
    // fixed-rate wakeup forever
    let idle = RetryPolicy {
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(50),
    };
    let mut idle_polls = 0u32;
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                idle_polls = 0;
                let s = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, s);
                }));
                // reap finished connection threads
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                idle_polls = idle_polls.saturating_add(1);
                std::thread::sleep(idle.backoff(idle_polls));
            }
            Err(_) => break,
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

/// Per-connection I/O thread: framing + parsing + validation. Compute
/// never happens here — `knn` goes through the shared queue.
fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>)
               -> std::io::Result<()> {
    // short read timeout so connection threads notice shutdown instead of
    // blocking forever while stop() joins them; partial lines accumulate
    // in `acc` across timeouts, so framing is never corrupted
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    stream.set_nodelay(true)?; // line-oriented RPC: Nagle adds ~40ms p50
    let mut writer = stream.try_clone()?;
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        // extract one complete line from the accumulator, else read more
        let line = loop {
            if let Some(pos) = acc.iter().position(|&b| b == b'\n') {
                let mut l: Vec<u8> = acc.drain(..=pos).collect();
                l.pop(); // strip newline
                break String::from_utf8_lossy(&l).into_owned();
            }
            match stream.read(&mut chunk) {
                Ok(0) => return Ok(()), // client closed
                Ok(n) => acc.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        };
        let t0 = Instant::now();
        let (label, resp): (&'static str, Json) =
            match Json::parse(line.trim()) {
                Err(e) => ("op:other",
                           err_json(&format!("bad json: {e}"))),
                Ok(req) => {
                    match req.get("op").and_then(|o| o.as_str()) {
                        Some("ping") => {
                            ("op:ping",
                             Json::obj(vec![("ok", Json::Bool(true))]))
                        }
                        Some("stats") => ("op:stats", stats_json(&shared)),
                        Some("shutdown") => {
                            shared.shutdown.store(true, Ordering::SeqCst);
                            shared.queue_cv.notify_all();
                            ("op:shutdown",
                             Json::obj(vec![("ok", Json::Bool(true))]))
                        }
                        Some("knn") => ("op:knn",
                                        handle_knn(&req, &shared)),
                        Some("epoch-bump") => ("op:epoch-bump",
                                               epoch_bump_json(&shared)),
                        Some("reshard") => ("op:reshard",
                                            reshard_json(&req, &shared)),
                        _ => ("op:other", err_json("unknown op")),
                    }
                }
            };
        record_route(&shared, label, t0.elapsed());
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Validate a knn request and route it through the result cache and the
/// worker pool. Shared by the line protocol ([`handle_conn`]) and the
/// HTTP front door (`POST /knn`), so both speak the same validation,
/// deadline-stamping, admission and caching behavior.
pub(crate) fn handle_knn(req: &Json, shared: &Shared) -> Json {
    let Some(qarr) = req.get("query").and_then(|q| q.as_arr()) else {
        return err_json("missing query");
    };
    let query: Vec<f32> = qarr
        .iter()
        .filter_map(|v| v.as_f64().map(|x| x as f32))
        .collect();
    if query.len() != shared.data.d {
        return err_json(&format!(
            "query dim {} != dataset dim {}", query.len(), shared.data.d));
    }
    let k = req.get("k").and_then(|v| v.as_usize()).unwrap_or(1);
    if k == 0 || k >= shared.data.n {
        return err_json("k out of range");
    }
    // the budget clock starts here, at validation — queue wait counts.
    // A request-level `deadline_ms` overrides the server default; the
    // override cannot be 0 ("no budget") because an unbounded query in
    // a budgeted deployment would defeat the operator's worst-case
    // latency bound.
    let deadline_ms = match req.get("deadline_ms") {
        None => shared.config.deadline_ms,
        Some(v) => match v.as_f64() {
            Some(ms) if ms >= 1.0 && ms == ms.trunc() => ms as u64,
            _ => {
                return err_json("deadline_ms must be an integer >= 1");
            }
        },
    };
    let deadline = (deadline_ms > 0)
        .then(|| Instant::now() + Duration::from_millis(deadline_ms));
    // the same content hash seeds the compute stream and keys the
    // cache: "same key" and "same answer bytes" are one property
    let seed = hash_query(&query, k);
    let t0 = Instant::now();
    let cache_key = shared.cache.as_ref().map(|_| CacheKey {
        query_hash: seed,
        k,
        eps_bits: shared.config.params.epsilon.to_bits(),
        delta_bits: shared.config.params.delta.to_bits(),
        fingerprint: shared.fingerprint,
        epoch: shared.epoch.load(Ordering::SeqCst),
    });
    // a hit skips the bandit entirely: answered before admission, so it
    // costs no queue slot even on an overloaded server, and well within
    // any deadline budget
    if let (Some(cache), Some(key)) = (&shared.cache, &cache_key) {
        if let Some(resp) = cache.lock().unwrap().get(key, &query) {
            shared.latencies.lock().unwrap().record(t0.elapsed());
            return resp;
        }
    }
    let cached_query = cache_key.is_some().then(|| query.clone());
    let resp = submit_and_wait(shared, query, k, seed, deadline);
    if resp.get("ok") == Some(&Json::Bool(true)) {
        shared.latencies.lock().unwrap().record(t0.elapsed());
        // only full-coverage successes enter the cache: a degraded
        // (coverage-annotated) answer depends on which shards happened
        // to be alive, and error/overload/deadline answers must always
        // be recomputed
        if resp.get("coverage").is_none() {
            if let (Some(cache), Some(key), Some(q)) =
                (&shared.cache, cache_key, cached_query)
            {
                cache.lock().unwrap().insert(key, &q, resp.clone());
            }
        }
    }
    resp
}

/// Advance the placement epoch, orphaning every existing cache entry
/// (their keys can no longer match). The `epoch-bump` op / `POST
/// /admin/epoch-bump` — for operators rolling a dataset or placement
/// change through a ring behind a warm front door.
pub(crate) fn epoch_bump_json(shared: &Shared) -> Json {
    let epoch = shared.epoch.fetch_add(1, Ordering::SeqCst) + 1;
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("epoch", Json::Num(epoch as f64)),
    ])
}

/// The `reshard` admin op (`POST /admin/reshard`): rebalance the ring
/// under live traffic. Three phases, each of which leaves the old
/// placement serving untouched if it fails:
///
/// 1. **Transfer** — stream every shard of the dataset to its staging
///    target(s) ([`reshard_to`]) and fingerprint-verify each installed
///    dataset against `wire::dataset_fingerprint` of the rows sent.
/// 2. **Open** — connect a fresh [`RingClient`] to the new placement
///    with the new epoch pinned (`expect_epoch`), so a leftover
///    old-placement endpoint can never join the connection set.
/// 3. **Flip** — swap placement, shared ring client and result-cache
///    epoch as one unit. Workers drain the batch in flight on the old
///    client and adopt the new one at their next batch boundary; the
///    automatic cache-epoch bump orphans every entry computed on the
///    old placement, no manual `epoch-bump` needed.
///
/// Request: `{"op":"reshard", "to":[spec,...], "epoch":e?}` — `to` is
/// one endpoint spec per shard (replicas `|`-separated, targets must
/// be staging servers: `shard-serve --staging`); `epoch` defaults to
/// the current placement epoch + 1 and must advance it.
pub(crate) fn reshard_json(req: &Json, shared: &Shared) -> Json {
    if shared.config.remote.is_empty() {
        return err_json("reshard requires a remote ring (--remote): a \
                         local engine has no placement to change");
    }
    let Some(to) = req.get("to").and_then(|t| t.as_arr()) else {
        return err_json("missing to: array of endpoint specs (one per \
                         shard; replicas |-separated)");
    };
    let mut specs = Vec::with_capacity(to.len());
    for v in to {
        match v.as_str() {
            Some(s) if !s.trim().is_empty() => specs.push(s.to_string()),
            _ => return err_json("to entries must be non-empty strings"),
        }
    }
    if specs.is_empty() {
        return err_json("to must name at least one endpoint");
    }
    let cur = shared.placement.lock().unwrap().epoch.unwrap_or(0);
    let epoch = match req.get("epoch") {
        None => cur + 1,
        Some(v) => match v.as_f64() {
            Some(e) if e >= 0.0 && e == e.trunc() => e as u64,
            _ => return err_json("epoch must be a non-negative integer"),
        },
    };
    if epoch <= cur {
        return err_json(&format!(
            "epoch {epoch} does not advance the current placement \
             epoch {cur} — each reshard must move forward"));
    }
    let map = match PlacementMap::parse(&specs) {
        Ok(m) => m,
        Err(e) => return err_json(&format!("bad placement: {e}")),
    };
    let timeout =
        Some(Duration::from_millis(shared.config.io_timeout_ms.max(1)));
    if let Err(e) = reshard_to(&shared.data, &map, epoch, timeout) {
        return err_json(&format!(
            "reshard aborted (old placement keeps serving): {e}"));
    }
    let opts = RemoteOptions {
        degraded: shared.config.degraded,
        timeout,
        expect_epoch: Some(epoch),
        ..RemoteOptions::default()
    };
    let fresh = match RingClient::connect_opts(&map, opts) {
        Ok(c) => Arc::new(c),
        Err(e) => {
            return err_json(&format!(
                "new placement verified but unreachable (old placement \
                 keeps serving): {e}"));
        }
    };
    {
        let mut p = shared.placement.lock().unwrap();
        p.endpoints = specs;
        p.epoch = Some(epoch);
    }
    *shared.ring.lock().unwrap() = Some(fresh);
    // the auto cache bump: stale hits across the flip are impossible
    // even though the dataset fingerprint did not change
    let cache_epoch = shared.epoch.fetch_add(1, Ordering::SeqCst) + 1;
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("placement_epoch", Json::Num(epoch as f64)),
        ("epoch", Json::Num(cache_epoch as f64)),
        ("shards", Json::Num(map.n_shards() as f64)),
    ])
}

/// Probe every endpoint of the current placement concurrently (one
/// short-lived stats connection each) and render per-endpoint health —
/// the `ring` array of `stats` / `GET /metrics`. Local configurations
/// report an empty array; an unreachable endpoint reports `ok:false`
/// with the probe error instead of failing the whole stats call.
fn ring_health_json(shared: &Shared) -> Json {
    let endpoints: Vec<String> = shared
        .placement
        .lock()
        .unwrap()
        .endpoints
        .iter()
        .flat_map(|spec| spec.split('|').map(|e| e.trim().to_string()))
        .collect();
    let timeout =
        Duration::from_millis(shared.config.io_timeout_ms.max(1));
    let probes: Vec<Result<EndpointStats, String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = endpoints
                .iter()
                .map(|ep| {
                    scope.spawn(move || endpoint_stats(ep, Some(timeout)))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(
                        |_| Err("stats probe panicked".into()))
                })
                .collect()
        });
    Json::Arr(
        endpoints
            .iter()
            .zip(probes)
            .map(|(ep, probe)| match probe {
                Ok(st) => Json::obj(vec![
                    ("endpoint", Json::Str(ep.clone())),
                    ("ok", Json::Bool(true)),
                    ("shard", Json::Num(st.shard as f64)),
                    ("of", Json::Num(st.of as f64)),
                    ("live_conns", Json::Num(st.live_conns as f64)),
                    ("epoch", Json::Num(st.epoch as f64)),
                    ("fingerprint",
                     Json::Str(format!("{:#018x}", st.data_hash))),
                ]),
                Err(e) => Json::obj(vec![
                    ("endpoint", Json::Str(ep.clone())),
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ]),
            })
            .collect(),
    )
}

/// Record one request's wall-clock under its route/op label ("POST
/// /knn" for HTTP, "op:knn" for the line protocol, ...). Labels are
/// `&'static str` by construction, so client input can never grow the
/// map; each label owns an independent [`LatencyStats`] ring window.
pub(crate) fn record_route(shared: &Shared, label: &'static str,
                           elapsed: Duration) {
    shared
        .route_lat
        .lock()
        .unwrap()
        .entry(label)
        .or_default()
        .record(elapsed);
}

/// The `routes` object of `stats`: per-route/per-op latency summaries
/// over each label's retained window, plus lifetime counts.
fn routes_json(shared: &Shared) -> Json {
    let rl = shared.route_lat.lock().unwrap();
    Json::obj(
        rl.iter()
            .map(|(label, l)| {
                (*label,
                 Json::obj(vec![
                     ("count", Json::Num(l.count() as f64)),
                     ("mean_us",
                      Json::Num(l.mean().as_micros() as f64)),
                     ("p50_us",
                      Json::Num(l.percentile(50.0).as_micros() as f64)),
                     ("p99_us",
                      Json::Num(l.percentile(99.0).as_micros() as f64)),
                 ]))
            })
            .collect(),
    )
}

/// The `stats` body, shared verbatim with `GET /metrics` on the HTTP
/// front door — one set of counters, two transports.
pub(crate) fn stats_json(shared: &Shared) -> Json {
    let lat = shared.latencies.lock().unwrap();
    let batches = shared.batches.lock().unwrap();
    let blat = batches.latency();
    let (cache_hits, cache_misses, cache_len) = match &shared.cache {
        Some(c) => {
            let c = c.lock().unwrap();
            (c.hits(), c.misses(), c.len())
        }
        None => (0, 0, 0),
    };
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("queries",
         Json::Num(shared.total_queries.load(Ordering::Relaxed) as f64)),
        ("units",
         Json::Num(shared.total_units.load(Ordering::Relaxed) as f64)),
        ("p50_us", Json::Num(lat.percentile(50.0).as_micros() as f64)),
        ("p99_us", Json::Num(lat.percentile(99.0).as_micros() as f64)),
        ("batches", Json::Num(batches.batches() as f64)),
        ("mean_batch", Json::Num(batches.mean_batch())),
        ("max_batch", Json::Num(batches.max_batch() as f64)),
        ("batch_p50_us",
         Json::Num(blat.percentile(50.0).as_micros() as f64)),
        ("batch_p99_us",
         Json::Num(blat.percentile(99.0).as_micros() as f64)),
        ("workers",
         Json::Num(shared.config.n_workers.max(1) as f64)),
        ("batch_wait_us",
         Json::Num(shared.config.batch_wait_us as f64)),
        ("shed", Json::Num(batches.shed() as f64)),
        ("deadline_exceeded",
         Json::Num(batches.deadline_exceeded() as f64)),
        // speculative pipelining accounting (all 0 with --speculate
        // off or a local engine): speculated == confirmed + discarded
        ("speculated", Json::Num(batches.speculated() as f64)),
        ("spec_confirmed",
         Json::Num(batches.spec_confirmed() as f64)),
        ("spec_discarded",
         Json::Num(batches.spec_discarded() as f64)),
        // per-route/per-op latency windows (line-protocol ops carry an
        // "op:" prefix; HTTP routes their method + path)
        ("routes", routes_json(shared)),
        ("cache_hits", Json::Num(cache_hits as f64)),
        ("cache_misses", Json::Num(cache_misses as f64)),
        ("cache_entries", Json::Num(cache_len as f64)),
        ("epoch",
         Json::Num(shared.epoch.load(Ordering::SeqCst) as f64)),
        // hex string: a u64 fingerprint does not survive the f64 JSON
        // number type; same `{:#018x}` rendering as ring-stats
        ("fingerprint",
         Json::Str(format!("{:#018x}", shared.fingerprint))),
        // placement visibility: the epoch the workers' ring is pinned
        // to (0 until the first reshard) and a live per-endpoint
        // health probe of the current placement (empty when local)
        ("placement_epoch",
         Json::Num(shared.placement.lock().unwrap().epoch.unwrap_or(0)
                   as f64)),
        ("ring", ring_health_json(shared)),
    ])
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Structured answer for a query whose deadline budget ran out, with
/// `context` naming where the budget died ("queue wait" / "compute").
fn deadline_json(context: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error",
         Json::Str(format!("deadline exceeded: query budget exhausted \
                            during {context}"))),
        ("kind", Json::Str("deadline_exceeded".into())),
    ])
}

/// Structured answer for a query shed at admission. The `retry_after_ms`
/// hint is the observed p50 batch latency (roughly one queue drain), so
/// well-behaved clients back off just long enough for the queue to make
/// room.
///
/// Cold fallback: before any batch has completed there is no observed
/// drain time, and a constant hint would be a lie in either direction.
/// Derive it from what the operator configured instead — the batching
/// linger (`batch_wait_us`, the floor any batch takes) and the deadline
/// budget (`deadline_ms`, the worst case one admitted batch may
/// legitimately run) — and only fall back to a generic 50 ms when
/// neither knob is set.
fn overload_json(shared: &Shared) -> Json {
    let p50 = shared
        .batches
        .lock()
        .unwrap()
        .latency()
        .percentile(50.0)
        .as_millis() as u64;
    let retry_after = if p50 > 0 {
        p50
    } else {
        let linger_ms = shared.config.batch_wait_us.div_ceil(1000);
        let derived = linger_ms.max(shared.config.deadline_ms);
        if derived == 0 { 50 } else { derived }
    };
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error",
         Json::Str(format!("overloaded: queue full ({} queued)",
                           shared.config.max_queue))),
        ("kind", Json::Str("overload".into())),
        ("retry_after_ms", Json::Num(retry_after as f64)),
    ])
}

/// Extract the message from a caught panic payload (compute panics in
/// this codebase carry `String` or `&str` payloads).
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> Option<&str> {
    payload
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| payload.downcast_ref::<&str>().copied())
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> std::io::Result<Json> {
        self.send_raw(&req.to_string())
    }

    /// Send a raw line (not necessarily valid JSON) and parse the
    /// response — lets tests exercise the malformed-input path.
    pub fn send_raw(&mut self, line: &str) -> std::io::Result<Json> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Json::parse(resp.trim()).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })
    }

    pub fn knn(&mut self, query: &[f32], k: usize)
               -> std::io::Result<(Vec<u32>, Vec<f64>, u64)> {
        let req = Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(query)),
            ("k", Json::Num(k as f64)),
        ]);
        let resp = self.request(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
            ));
        }
        let ids = resp
            .get("ids")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as u32))
                 .collect())
            .unwrap_or_default();
        let dists = resp
            .get("dists")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();
        let units = resp.get("units").and_then(|v| v.as_f64()).unwrap_or(0.0)
            as u64;
        Ok((ids, dists, units))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn free_port_config() -> ServerConfig {
        ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serves_knn_queries() {
        let ds = synthetic::image_like(60, 128, 131);
        let q = ds.row_vec(11);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let (ids, dists, units) = cl.knn(&q, 3).unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(dists.len(), 3);
        assert!(units > 0);
        assert_eq!(ids[0], 11, "self row should be its own 1-NN");
        srv.stop();
    }

    #[test]
    fn stats_and_ping() {
        let ds = synthetic::image_like(40, 64, 132);
        let q = ds.row_vec(0);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let pong = cl
            .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
            .unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
        let _ = cl.knn(&q, 1).unwrap();
        let stats = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("queries").unwrap().as_usize(), Some(1));
        assert!(stats.get("units").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(stats.get("batches").unwrap().as_usize(), Some(1));
        assert!(stats.get("mean_batch").unwrap().as_f64().unwrap() >= 1.0);
        srv.stop();
    }

    #[test]
    fn stats_surface_route_latencies_and_speculation_counters() {
        let ds = synthetic::image_like(40, 64, 142);
        let q = ds.row_vec(5);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let _ = cl
            .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
            .unwrap();
        let _ = cl.knn(&q, 2).unwrap();
        let stats = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        // local engine, speculation off: all three counters pinned at 0
        for f in ["speculated", "spec_confirmed", "spec_discarded"] {
            assert_eq!(stats.get(f).and_then(|v| v.as_f64()), Some(0.0),
                       "{f} should be 0 on a local server");
        }
        // every line-protocol op served so far has its own latency
        // window under an "op:" label
        let routes = stats.get("routes").expect("routes object");
        for op in ["op:ping", "op:knn"] {
            let r = routes.get(op)
                .unwrap_or_else(|| panic!("missing route {op}"));
            assert_eq!(r.get("count").and_then(|v| v.as_usize()), Some(1),
                       "{op} count");
            assert!(r.get("p99_us").and_then(|v| v.as_f64()).is_some());
            assert!(r.get("mean_us").and_then(|v| v.as_f64()).is_some());
        }
        // the stats op that produced this body is itself recorded only
        // for *prior* calls; a second read must show it
        let stats2 = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        let r = stats2.get("routes").unwrap().get("op:stats").unwrap();
        assert!(r.get("count").and_then(|v| v.as_usize()).unwrap() >= 1);
        srv.stop();
    }

    #[test]
    fn rejects_bad_requests() {
        let ds = synthetic::image_like(30, 32, 133);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let resp = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("query", Json::f32_array(&[1.0, 2.0])), // wrong dim
                ("k", Json::Num(1.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // malformed json
        let resp2 = cl.send_raw("{not json").unwrap();
        assert_eq!(resp2.get("ok"), Some(&Json::Bool(false)));
        srv.stop();
    }

    #[test]
    fn full_queue_sheds_with_retry_hint() {
        // drive submit_and_wait directly against a hand-built Shared
        // with no workers: one pre-queued job fills the bounded queue,
        // so the next submit must shed immediately (it would hang
        // forever waiting otherwise — no worker will ever answer)
        let ds = synthetic::image_like(30, 16, 135);
        let q = ds.row_vec(0);
        let shared = test_shared(
            ds, ServerConfig { max_queue: 1, ..Default::default() });
        shared.queue.lock().unwrap().push_back(Job {
            query: q.clone(),
            k: 1,
            seed: 0,
            deadline: None,
            done: Arc::new((Mutex::new(None), Condvar::new())),
        });
        let resp = submit_and_wait(&shared, q, 1, 0, None);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("kind").and_then(|k| k.as_str()),
                   Some("overload"));
        let hint = resp
            .get("retry_after_ms")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(hint >= 1.0, "retry hint must be actionable: {hint}");
        assert_eq!(shared.batches.lock().unwrap().shed(), 1);
        // the shed query never consumed a queue slot
        assert_eq!(shared.queue.lock().unwrap().len(), 1);
    }

    /// A workerless `Shared` for driving the admission path directly.
    fn test_shared(data: DenseDataset, config: ServerConfig) -> Shared {
        let placement = Mutex::new(Placement {
            endpoints: config.remote.clone(),
            epoch: (config.epoch > 0).then_some(config.epoch),
        });
        Shared {
            data,
            config,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            total_units: AtomicU64::new(0),
            total_queries: AtomicU64::new(0),
            latencies: Mutex::new(LatencyStats::default()),
            route_lat: Mutex::new(BTreeMap::new()),
            batches: Mutex::new(BatchStats::default()),
            ring: Mutex::new(None),
            placement,
            fingerprint: 0,
            epoch: AtomicU64::new(0),
            cache: None,
            shutdown: AtomicBool::new(false),
        }
    }

    #[test]
    fn reshard_validates_before_touching_the_network() {
        // local engine: nothing to reshard
        let ds = synthetic::image_like(30, 16, 141);
        let local = test_shared(ds.clone(), ServerConfig::default());
        let req = Json::obj(vec![
            ("op", Json::Str("reshard".into())),
            ("to", Json::Arr(vec![Json::Str("127.0.0.1:1".into())])),
        ]);
        let resp = reshard_json(&req, &local);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get("error").and_then(|e| e.as_str()).unwrap()
                    .contains("remote"));

        // remote config, but the requested epoch does not advance the
        // current placement epoch — rejected before any transfer
        let remote = test_shared(
            ds,
            ServerConfig { remote: vec!["127.0.0.1:1".into()],
                           ..Default::default() });
        let req = Json::obj(vec![
            ("op", Json::Str("reshard".into())),
            ("to", Json::Arr(vec![Json::Str("127.0.0.1:1".into())])),
            ("epoch", Json::Num(0.0)),
        ]);
        let resp = reshard_json(&req, &remote);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get("error").and_then(|e| e.as_str()).unwrap()
                    .contains("advance"));
    }

    #[test]
    fn cold_retry_hint_derives_from_configured_knobs() {
        // no batch has completed → no observed p50; the hint must come
        // from the configured linger/deadline, not a constant
        let ds = synthetic::image_like(30, 16, 138);
        let linger = test_shared(
            ds.clone(),
            ServerConfig { max_queue: 1, batch_wait_us: 120_000,
                           ..Default::default() });
        let hint = overload_json(&linger)
            .get("retry_after_ms").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(hint, 120.0, "hint should be the 120ms linger");

        let budget = test_shared(
            ds.clone(),
            ServerConfig { max_queue: 1, deadline_ms: 7_000,
                           ..Default::default() });
        let hint = overload_json(&budget)
            .get("retry_after_ms").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(hint, 7_000.0, "hint should be the deadline budget");

        let bare = test_shared(
            ds, ServerConfig { max_queue: 1, ..Default::default() });
        let hint = overload_json(&bare)
            .get("retry_after_ms").and_then(|v| v.as_f64()).unwrap();
        assert_eq!(hint, 50.0, "no signal at all → generic fallback");
    }

    #[test]
    fn identical_requests_answer_identical_bytes() {
        // seeded serving compute: with the cache OFF, repeating a
        // request must still produce byte-identical responses across
        // batches and workers — the property the cache contract (and
        // the epoch-flip bitwise assertion) rests on
        let ds = synthetic::image_like(60, 64, 139);
        let q = ds.row_vec(7);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let req = Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(&q)),
            ("k", Json::Num(3.0)),
        ]);
        let a = cl.request(&req).unwrap().to_string();
        let b = cl.request(&req).unwrap().to_string();
        assert_eq!(a, b, "serving compute must be deterministic");
        srv.stop();
    }

    #[test]
    fn epoch_bump_op_advances_epoch() {
        let ds = synthetic::image_like(30, 16, 140);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let resp = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("epoch-bump".into())),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("epoch").and_then(|v| v.as_usize()), Some(1));
        let stats = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("epoch").and_then(|v| v.as_usize()), Some(1));
        srv.stop();
    }

    #[test]
    fn expired_deadline_answers_structured_error() {
        // batch_wait_us makes the worker linger 50ms on a non-full
        // batch, so a 1ms request budget reliably expires in-queue and
        // the pre-compute filter answers it
        let ds = synthetic::image_like(40, 32, 136);
        let q = ds.row_vec(3);
        let cfg = ServerConfig {
            batch_wait_us: 50_000,
            ..free_port_config()
        };
        let mut srv = Server::start(ds, cfg).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let resp = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("query", Json::f32_array(&q)),
                ("k", Json::Num(1.0)),
                ("deadline_ms", Json::Num(1.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("kind").and_then(|k| k.as_str()),
                   Some("deadline_exceeded"));
        let stats = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        assert!(stats
                    .get("deadline_exceeded")
                    .and_then(|v| v.as_f64())
                    .unwrap()
                >= 1.0);
        // a generous budget on the same server still answers normally
        let resp2 = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("query", Json::f32_array(&q)),
                ("k", Json::Num(1.0)),
                ("deadline_ms", Json::Num(600_000.0)),
            ]))
            .unwrap();
        assert_eq!(resp2.get("ok"), Some(&Json::Bool(true)));
        srv.stop();
    }

    #[test]
    fn zero_deadline_override_is_rejected() {
        // per-request deadline_ms=0 would mean "unbounded", defeating
        // the operator's budget — reject it at validation
        let ds = synthetic::image_like(30, 16, 137);
        let q = ds.row_vec(0);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let resp = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("query", Json::f32_array(&q)),
                ("k", Json::Num(1.0)),
                ("deadline_ms", Json::Num(0.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp
                    .get("error")
                    .and_then(|e| e.as_str())
                    .unwrap()
                    .contains("deadline_ms"));
        srv.stop();
    }

    #[test]
    fn concurrent_clients() {
        let ds = synthetic::image_like(50, 64, 134);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| ds.row_vec(i)).collect();
        let srv = Server::start(ds, free_port_config()).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = queries
            .into_iter()
            .enumerate()
            .map(|(i, q)| {
                std::thread::spawn(move || {
                    let mut cl = Client::connect(&addr).unwrap();
                    let (ids, _, _) = cl.knn(&q, 1).unwrap();
                    assert_eq!(ids[0] as usize, i);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.total_queries(), 8);
    }
}
