//! k-NN query server: TCP, line-delimited JSON, worker thread pool with a
//! shared queue (dynamic batching of queued queries per worker pass).
//!
//! Python never runs here — this is the L3 request path. Each worker owns
//! its RNG fork and distance counter; counters are merged into server
//! totals for the metrics endpoint.
//!
//! Protocol (one JSON object per line):
//!   request:  {"op":"knn",   "query":[f32...], "k":5}
//!             {"op":"stats"}
//!             {"op":"ping"}
//!             {"op":"shutdown"}
//!   response: {"ok":true, "ids":[...], "dists":[...], "units":u}
//!             {"ok":true, "queries":q, "units":u, "p50_us":_, "p99_us":_}
//!             {"ok":false, "error":"..."}

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::arms::ScalarEngine;
use crate::coordinator::bandit::BanditParams;
use crate::coordinator::knn::knn_query_dense;
use crate::data::dense::{DenseDataset, Metric};
use crate::metrics::{Counter, LatencyStats};
use crate::runtime::native::NativeEngine;
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub metric: Metric,
    pub params: BanditParams,
    /// worker threads handling connections
    pub n_workers: usize,
    /// use the optimized native engine (true) or the scalar reference
    pub native_engine: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            metric: Metric::L2Sq,
            params: BanditParams::default(),
            n_workers: 4,
            native_engine: true,
        }
    }
}

struct Shared {
    data: DenseDataset,
    config: ServerConfig,
    total_units: AtomicU64,
    total_queries: AtomicU64,
    latencies: Mutex<LatencyStats>,
    shutdown: AtomicBool,
}

/// Running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving `data` in background threads.
    pub fn start(data: DenseDataset, config: ServerConfig)
                 -> std::io::Result<Server> {
        let listener = TcpListener::bind(&config.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            data,
            config,
            total_units: AtomicU64::new(0),
            total_queries: AtomicU64::new(0),
            latencies: Mutex::new(LatencyStats::default()),
            shutdown: AtomicBool::new(false),
        });
        let accept_shared = shared.clone();
        let handle = std::thread::spawn(move || {
            accept_loop(listener, accept_shared);
        });
        Ok(Server { addr, shared, accept_handle: Some(handle) })
    }

    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }

    pub fn total_queries(&self) -> u64 {
        self.shared.total_queries.load(Ordering::Relaxed)
    }

    pub fn total_units(&self) -> u64 {
        self.shared.total_units.load(Ordering::Relaxed)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conn_id = 0u64;
    let mut handles = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                conn_id += 1;
                let s = shared.clone();
                let id = conn_id;
                handles.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, s, id);
                }));
                // reap finished connection threads
                handles.retain(|h| !h.is_finished());
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    for h in handles {
        let _ = h.join();
    }
}

fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>, conn_id: u64)
               -> std::io::Result<()> {
    // short read timeout so connection threads notice shutdown instead of
    // blocking forever while stop() joins them; partial lines accumulate
    // in `acc` across timeouts, so framing is never corrupted
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    stream.set_nodelay(true)?; // line-oriented RPC: Nagle adds ~40ms p50
    let mut writer = stream.try_clone()?;
    let mut rng = Rng::new(0xC0FFEE ^ conn_id);
    let mut scalar = ScalarEngine;
    let mut native = NativeEngine::default();
    let mut acc: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        // extract one complete line from the accumulator, else read more
        let line = loop {
            if let Some(pos) = acc.iter().position(|&b| b == b'\n') {
                let mut l: Vec<u8> = acc.drain(..=pos).collect();
                l.pop(); // strip newline
                break String::from_utf8_lossy(&l).into_owned();
            }
            match stream.read(&mut chunk) {
                Ok(0) => return Ok(()), // client closed
                Ok(n) => acc.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        };
        let resp = match Json::parse(line.trim()) {
            Err(e) => err_json(&format!("bad json: {e}")),
            Ok(req) => {
                match req.get("op").and_then(|o| o.as_str()) {
                    Some("ping") => Json::obj(vec![("ok", Json::Bool(true))]),
                    Some("stats") => stats_json(&shared),
                    Some("shutdown") => {
                        shared.shutdown.store(true, Ordering::SeqCst);
                        Json::obj(vec![("ok", Json::Bool(true))])
                    }
                    Some("knn") => {
                        let use_native = shared.config.native_engine;
                        if use_native {
                            handle_knn(&req, &shared, &mut native, &mut rng)
                        } else {
                            handle_knn(&req, &shared, &mut scalar, &mut rng)
                        }
                    }
                    _ => err_json("unknown op"),
                }
            }
        };
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

fn handle_knn<E: crate::coordinator::arms::PullEngine>(
    req: &Json, shared: &Shared, engine: &mut E, rng: &mut Rng) -> Json {
    let Some(qarr) = req.get("query").and_then(|q| q.as_arr()) else {
        return err_json("missing query");
    };
    let query: Vec<f32> = qarr
        .iter()
        .filter_map(|v| v.as_f64().map(|x| x as f32))
        .collect();
    if query.len() != shared.data.d {
        return err_json(&format!(
            "query dim {} != dataset dim {}", query.len(), shared.data.d));
    }
    let k = req.get("k").and_then(|v| v.as_usize()).unwrap_or(1);
    if k == 0 || k >= shared.data.n {
        return err_json("k out of range");
    }
    let mut params = shared.config.params.clone();
    params.k = k;
    let mut counter = Counter::new();
    let t0 = Instant::now();
    let res = knn_query_dense(&shared.data, &query, shared.config.metric,
                              &params, engine, rng, &mut counter);
    let elapsed = t0.elapsed();
    shared.total_units.fetch_add(counter.get(), Ordering::Relaxed);
    shared.total_queries.fetch_add(1, Ordering::Relaxed);
    shared.latencies.lock().unwrap().record(elapsed);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("ids",
         Json::usize_array(
             &res.ids.iter().map(|&i| i as usize).collect::<Vec<_>>())),
        ("dists", Json::f32_array(
            &res.dists.iter().map(|&d| d as f32).collect::<Vec<_>>())),
        ("units", Json::Num(counter.get() as f64)),
    ])
}

fn stats_json(shared: &Shared) -> Json {
    let lat = shared.latencies.lock().unwrap();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("queries",
         Json::Num(shared.total_queries.load(Ordering::Relaxed) as f64)),
        ("units",
         Json::Num(shared.total_units.load(Ordering::Relaxed) as f64)),
        ("p50_us", Json::Num(lat.percentile(50.0).as_micros() as f64)),
        ("p99_us", Json::Num(lat.percentile(99.0).as_micros() as f64)),
    ])
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> std::io::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim()).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e)
        })
    }

    pub fn knn(&mut self, query: &[f32], k: usize)
               -> std::io::Result<(Vec<u32>, Vec<f64>, u64)> {
        let req = Json::obj(vec![
            ("op", Json::Str("knn".into())),
            ("query", Json::f32_array(query)),
            ("k", Json::Num(k as f64)),
        ]);
        let resp = self.request(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown")
                    .to_string(),
            ));
        }
        let ids = resp
            .get("ids")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as u32))
                 .collect())
            .unwrap_or_default();
        let dists = resp
            .get("dists")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();
        let units = resp.get("units").and_then(|v| v.as_f64()).unwrap_or(0.0)
            as u64;
        Ok((ids, dists, units))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn free_port_config() -> ServerConfig {
        ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() }
    }

    #[test]
    fn serves_knn_queries() {
        let ds = synthetic::image_like(60, 128, 131);
        let q = ds.row_vec(11);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let (ids, dists, units) = cl.knn(&q, 3).unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(dists.len(), 3);
        assert!(units > 0);
        assert_eq!(ids[0], 11, "self row should be its own 1-NN");
        srv.stop();
    }

    #[test]
    fn stats_and_ping() {
        let ds = synthetic::image_like(40, 64, 132);
        let q = ds.row_vec(0);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let pong = cl
            .request(&Json::obj(vec![("op", Json::Str("ping".into()))]))
            .unwrap();
        assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
        let _ = cl.knn(&q, 1).unwrap();
        let stats = cl
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("queries").unwrap().as_usize(), Some(1));
        assert!(stats.get("units").unwrap().as_f64().unwrap() > 0.0);
        srv.stop();
    }

    #[test]
    fn rejects_bad_requests() {
        let ds = synthetic::image_like(30, 32, 133);
        let mut srv = Server::start(ds, free_port_config()).unwrap();
        let mut cl = Client::connect(&srv.addr).unwrap();
        let resp = cl
            .request(&Json::obj(vec![
                ("op", Json::Str("knn".into())),
                ("query", Json::f32_array(&[1.0, 2.0])), // wrong dim
                ("k", Json::Num(1.0)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // malformed json
        let resp2 = cl.request(&Json::Str("not an object".into()));
        assert!(resp2.is_ok());
        srv.stop();
    }

    #[test]
    fn concurrent_clients() {
        let ds = synthetic::image_like(50, 64, 134);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| ds.row_vec(i)).collect();
        let srv = Server::start(ds, free_port_config()).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = queries
            .into_iter()
            .enumerate()
            .map(|(i, q)| {
                std::thread::spawn(move || {
                    let mut cl = Client::connect(&addr).unwrap();
                    let (ids, _, _) = cl.knn(&q, 1).unwrap();
                    assert_eq!(ids[0] as usize, i);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.total_queries(), 8);
    }
}
